"""repro.obs — unified metrics & instrumentation layer (observability PR).

Covers the registry semantics (typed metrics, domain prefixes, JSON
round-trip, cross-shard merge, falsy no-op when disabled), the
sim-domain bit-identity contract (fast == event tiers, serial == pool
executors, fabric payload-by-level included), report surfacing
(RunReport / SweepReport / ServingReport ``.metrics`` with JSON
round-trip, zero rows when disabled), the roofline and bubble
identities the derivation guarantees, Perfetto counter tracks on the
Chrome trace export, and the search-profile promotion."""

import json

import pytest

from repro.api import (
    Experiment,
    ParallelPlan,
    RunReport,
    SearchSpace,
    SweepEngine,
    SweepReport,
    resolve_hardware,
)
from repro.core.hardware import tiled_cluster
from repro.core.trace import chrome_trace
from repro.obs.registry import (
    NULL_REGISTRY,
    MetricsRegistry,
    make_registry,
    summarize_metrics,
)
from repro.obs.tracks import (
    activity_counters,
    metrics_counters,
    serving_counters,
)
from repro.search.engine import run_search
from repro.serving.system import ServingSpec, simulate_serving
from repro.serving.workload import WorkloadSpec

from proptools import given

HW = "tpu_v5e_2x2"
ARCH = "yi-6b"

TINY_WORKLOAD = WorkloadSpec(rate=2.0, num_requests=10, seed=3,
                             prompt_mean=64, decode_mean=8,
                             prompt_cv=0.5, decode_cv=0.5)
TINY_SPEC = ServingSpec(workload=TINY_WORKLOAD, max_batch=4, ctx_bucket=128)


def _exp(engine="auto", metrics=True, plan=(2, 1, 2), micro=1, gb=8, **kw):
    pp, dp, tp = plan
    return Experiment(
        arch=ARCH, hardware=HW, seq_len=128,
        plan=ParallelPlan(pp=pp, dp=dp, tp=tp, microbatch=micro,
                          global_batch=gb),
        global_batch=gb, engine=engine, metrics=metrics, **kw)


def _sim_doc(report):
    return json.dumps(report.metrics["sim"], sort_keys=True)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_roundtrip_and_merge():
    reg = MetricsRegistry()
    reg.counter("host.sweep.jobs").inc(3)
    reg.counter("host.sweep.jobs").inc(2)
    reg.gauge("host.pool.workers").set(4)
    reg.histogram("host.shard.us").observe(10.0)
    reg.histogram("host.shard.us").observe(30.0)
    with reg.span("host.evaluate"):
        pass
    doc = reg.to_dict()
    assert doc["counters"]["host.sweep.jobs"] == 5
    assert doc["gauges"]["host.pool.workers"] == 4
    assert doc["histograms"]["host.shard.us"] == {
        "count": 2, "sum": 40.0, "min": 10.0, "max": 30.0}
    assert doc["counters"]["host.evaluate.calls"] == 1
    # round-trip is exact
    assert MetricsRegistry.from_dict(doc).to_dict() == doc
    # merge: counters add, gauges last-write, histograms combine exactly
    other = MetricsRegistry()
    other.counter("host.sweep.jobs").inc(7)
    other.gauge("host.pool.workers").set(2)
    other.histogram("host.shard.us").observe(5.0)
    other.merge_dict(doc)
    merged = other.to_dict()
    assert merged["counters"]["host.sweep.jobs"] == 12
    assert merged["gauges"]["host.pool.workers"] == 4
    assert merged["histograms"]["host.shard.us"] == {
        "count": 3, "sum": 45.0, "min": 5.0, "max": 30.0}


def test_registry_rejects_unprefixed_names():
    reg = MetricsRegistry()
    for bad in ("jobs", "sweep.jobs", "simjobs", "hostile.jobs"):
        with pytest.raises(ValueError):
            reg.counter(bad)
    reg.counter("sim.total_time")          # both domains are accepted
    reg.counter("host.sweep.jobs")


def test_null_registry_is_falsy_noop():
    assert not NULL_REGISTRY
    assert make_registry(False) is NULL_REGISTRY
    assert isinstance(make_registry(True), MetricsRegistry)
    NULL_REGISTRY.counter("host.x").inc(5)
    NULL_REGISTRY.gauge("host.y").set(1)
    NULL_REGISTRY.histogram("host.z").observe(2.0)
    with NULL_REGISTRY.span("host.w"):
        pass
    assert NULL_REGISTRY.to_dict() == {}
    assert NULL_REGISTRY.rows() == []


def test_summarize_metrics_text():
    rep = _exp().run()
    text = summarize_metrics(rep.metrics, title="t")
    assert text.startswith("== t ==")
    assert "[sim]" in text and "[host]" in text
    assert "bubble_ratio" in text
    assert "(none recorded" in summarize_metrics(None)


# ---------------------------------------------------------------------------
# report surfacing: attach when enabled, zero rows when disabled
# ---------------------------------------------------------------------------

def test_run_metrics_disabled_adds_nothing():
    rep = _exp(metrics=False).run()
    assert rep.metrics is None
    assert "metrics" not in rep.to_dict()
    assert "metrics" not in json.loads(rep.to_json())


def test_run_metrics_roundtrip_and_shape():
    rep = _exp().run()
    m = rep.metrics
    assert set(m) == {"sim", "host"}
    sim = m["sim"]
    assert sim["total_time"] == rep.total_time
    assert sim["throughput"] == rep.throughput
    assert len(sim["stages"]["flops"]) == 2
    assert m["host"]["engine"] in ("fast", "event")
    # JSON round-trip preserves the document exactly
    back = RunReport.from_json(rep.to_json())
    assert back.metrics == m


def test_bubble_and_roofline_identities():
    rep = _exp().run()
    sim = rep.metrics["sim"]
    S = len(sim["stages"]["flops"])
    bub = sim["bubble"]
    # warmup + interior + drain + busy == S * total_time, exactly
    assert (bub["warmup"] + bub["interior"] + bub["drain"] + bub["busy"]
            == S * sim["total_time"])
    # headline bubble matches the schedule-level scalar the report carries
    assert sim["bubble_ratio"] == pytest.approx(rep.bubble_ratio, rel=1e-12)
    # roofline utilization is exactly flops / (total_time * tile peak)
    hw = resolve_hardware(HW)
    denom = sim["total_time"] * hw.tile.flops
    for u, f in zip(sim["stages"]["roofline_utilization"],
                    sim["stages"]["flops"]):
        assert u == pytest.approx(f / denom, rel=1e-12)
        assert 0.0 < u < 1.0


def test_fastpath_rejection_code_surfaced():
    # tiled_cluster in the default macro NoC mode is fast-ineligible:
    # auto falls back to the event tier and records why
    rep = Experiment(
        arch=ARCH, hardware=tiled_cluster(), seq_len=128,
        plan=ParallelPlan(pp=2, dp=1, tp=2, microbatch=1, global_batch=4),
        global_batch=4, engine="auto", metrics=True).run()
    host = rep.metrics["host"]
    assert host["engine"] == "event"
    rej = host["fastpath_rejection"]
    assert rej["code"] == "contention"
    assert "contention" in rej["reason"]


# ---------------------------------------------------------------------------
# sim-domain bit-identity: tiers, executors, fabric levels
# ---------------------------------------------------------------------------

@given(n_cases=6, seed=11)
def test_sim_metrics_identical_across_tiers(rng, case):
    from repro.core.fastpath import FastPathIneligible

    plans = [(2, 1, 2), (1, 2, 2), (2, 2, 1), (4, 1, 1)]
    pp, dp, tp = plans[int(rng.integers(len(plans)))]
    micro = int(rng.choice([1, 2]))
    gb = int(rng.choice([8, 16]))
    try:
        fast = _sim_doc(_exp(engine="fast", plan=(pp, dp, tp), micro=micro,
                             gb=gb).run())
    except FastPathIneligible:
        return          # draw needs the event tier; parity is vacuous
    event = _sim_doc(_exp(engine="event", plan=(pp, dp, tp), micro=micro,
                          gb=gb).run())
    assert fast == event


def test_sim_metrics_identical_serial_vs_pool():
    exp = Experiment(
        arch=ARCH, hardware=HW, seq_len=128, global_batch=8, metrics=True,
        search=SearchSpace(degrees=[(2, 1, 2), (1, 2, 2), (2, 2, 1)],
                           microbatch_sizes=(1, 2)))
    plans = exp.search.enumerate_plans(resolve_hardware(HW), 8)
    reports = {}
    for workers in (0, 2):
        eng = SweepEngine(workers=workers)
        try:
            reports[workers] = eng.sweep(exp, plans)
        finally:
            eng.close()
    a, b = reports[0], reports[2]
    assert json.dumps(a.metrics["sim"], sort_keys=True) == \
        json.dumps(b.metrics["sim"], sort_keys=True)
    assert [_sim_doc(r) for r in a.runs] == [_sim_doc(r) for r in b.runs]
    # host domain exists on both but is never compared
    assert a.metrics["host"]["counters"]["host.sweep.jobs"] == len(plans)
    assert b.metrics["host"]["counters"]["host.pool.shards"] >= 1
    # sweep-level JSON round-trip
    back = SweepReport.from_json(a.to_json())
    assert back.metrics == a.metrics


def test_sweep_metrics_disabled_adds_nothing():
    exp = Experiment(
        arch=ARCH, hardware=HW, seq_len=128, global_batch=8,
        search=SearchSpace(degrees=[(2, 1, 2), (1, 2, 2)],
                           microbatch_sizes=(1,)))
    rep = exp.sweep()
    assert rep.metrics is None
    assert "metrics" not in rep.to_dict()
    assert all(r.metrics is None for r in rep.runs)


def test_fabric_payload_by_level_parity():
    docs = {}
    for eng in ("fast", "event"):
        rep = Experiment(
            arch=ARCH, hardware=tiled_cluster(), seq_len=128,
            plan=ParallelPlan(pp=2, dp=1, tp=2, microbatch=1,
                              global_batch=4),
            global_batch=4, engine=eng, noc_mode="analytical",
            metrics=True).run()
        assert rep.metrics["host"]["engine"] == eng
        docs[eng] = rep.metrics["sim"]
    assert json.dumps(docs["fast"], sort_keys=True) == \
        json.dumps(docs["event"], sort_keys=True)
    levels = docs["fast"]["payload_by_level"]
    assert set(levels) == {"board", "node"}
    assert all(v > 0 for v in levels.values())


def test_pure_python_fallback_matches_numpy_path():
    # bench-smoke CI runs without numpy: the array.array fallback must
    # produce the same document up to float-association noise (sequential
    # vs pairwise summation)
    import math

    import repro.obs.simmetrics as sm

    def close(a, b):
        if isinstance(a, dict):
            return set(a) == set(b) and all(close(a[k], b[k]) for k in a)
        if isinstance(a, list):
            return len(a) == len(b) and all(
                close(x, y) for x, y in zip(a, b))
        if isinstance(a, float):
            return math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-12)
        return a == b

    np_doc = _exp(collect_timeline=True).run().metrics["sim"]
    saved, sm._np = sm._np, None
    try:
        py_doc = _exp(collect_timeline=True).run().metrics["sim"]
    finally:
        sm._np = saved
    assert "resources" in np_doc and "resources" in py_doc
    assert close(np_doc, py_doc)


# ---------------------------------------------------------------------------
# Perfetto counter tracks on the Chrome trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_counter_tracks():
    rep = _exp(collect_timeline=True).run()
    assert rep.trace is not None
    counters = activity_counters(rep.trace)
    counters.update(metrics_counters(rep.metrics, rep.trace.total_time))
    doc = chrome_trace(rep.trace, counters=counters)
    events = doc["traceEvents"]
    tracks = [e for e in events if e.get("ph") == "C"]
    assert tracks
    names = {e["name"] for e in tracks}
    assert "active_stages" in names and "bubble_ratio" in names
    for e in tracks:
        assert e["pid"] == 5
        assert isinstance(e["args"]["value"], (int, float))
    # counter series are time-ordered per name
    by_name = {}
    for e in tracks:
        by_name.setdefault(e["name"], []).append(e["ts"])
    for ts in by_name.values():
        assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# serving + search surfacing
# ---------------------------------------------------------------------------

def test_serving_metrics_attach_and_roundtrip():
    rep = simulate_serving("hymba-1.5b", "grayskull", None, TINY_SPEC,
                           metrics=True)
    m = rep.metrics
    assert set(m) == {"sim", "host"}
    assert m["sim"]["kv_cache"]["peak_bytes"] == rep.kv_peak_bytes
    assert m["sim"]["steps"]["decode"] == rep.steps["decode"]
    assert m["host"]["counters"]["host.serving.run.calls"] == 1
    assert json.loads(json.dumps(rep.to_dict()))["metrics"] == m
    # counter tracks for the serving trace export
    series = serving_counters(rep)
    assert "kv_occupancy_bytes" in series and "queue_depth" in series
    # disabled: no rows anywhere
    off = simulate_serving("hymba-1.5b", "grayskull", None, TINY_SPEC)
    assert off.metrics is None
    assert "metrics" not in off.to_dict()


def test_search_profile_and_metrics_promoted():
    exp = Experiment(
        arch=ARCH, hardware=HW, seq_len=128, global_batch=8, metrics=True,
        search=SearchSpace(degrees=[(2, 1, 2), (1, 2, 2), (2, 2, 1),
                                    (4, 1, 1)],
                           microbatch_sizes=(1, 2)))
    rep = run_search(exp, strategy="sh", budget=6, seed=0, profile=True)
    prof = rep.profile
    assert prof is not None and prof["generations"]
    assert all("jobs" in g for g in prof["generations"])
    m = rep.metrics
    assert m is not None
    assert m["sim"]["runs"] == len(rep.runs)
    host = m["host"]["counters"]
    assert host["host.search.evaluations"] >= len(rep.runs)
    assert host["host.search.generation.calls"] == len(prof["generations"])
