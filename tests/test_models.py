"""Per-arch smoke tests (spec-required): every assigned architecture at a
REDUCED config runs one forward/train step on CPU with finite outputs and
correct shapes; decode matches teacher-forced forward (strong AR-cache
correctness check)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, SHAPES, shape_applicable
from repro.launch.train import scale_arch
from repro.models import (
    RunCfg,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)

CFG = RunCfg(q_chunk=0, remat=False)
KEY = jax.random.PRNGKey(0)


def _batch(arch, B=2, S=32, key=KEY):
    if arch.embeds_input:
        return {"embeds": jax.random.normal(key, (B, S, arch.d_model)),
                "labels": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jax.random.randint(key, (B, S), 0, arch.vocab),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_grad(name):
    arch = scale_arch(get_config(name), "tiny")
    params = init_params(arch, KEY, CFG)
    batch = _batch(arch)
    logits, aux = jax.jit(lambda p, b: forward(
        arch, p, tokens=b.get("tokens"), embeds=b.get("embeds"), cfg=CFG))(params, batch)
    assert logits.shape == (2, 32, arch.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = loss_fn(arch, params, batch, CFG)
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: loss_fn(arch, p, batch, CFG)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ["yi-6b", "granite-moe-3b-a800m",
                                  "mamba2-2.7b", "hymba-1.5b",
                                  "llava-next-34b"])
def test_decode_matches_teacher_forced_forward(name):
    """decode_step over a prompt must reproduce forward()'s next-token
    logits at every position (KV cache + SSM state correctness).
    MoE capacity is batch-dependent, so use a drop-free capacity factor —
    with drops, decode-vs-forward divergence is expected MoE semantics."""
    arch = scale_arch(get_config(name), "tiny")
    arch = dataclasses.replace(arch, window=0 if arch.window else 0)  # full attn
    cfg = dataclasses.replace(CFG, capacity_factor=8.0)
    params = init_params(arch, KEY, cfg)
    B, S = 2, 12
    if arch.embeds_input:
        embeds = jax.random.normal(KEY, (B, S, arch.d_model))
        ref_logits, _ = forward(arch, params, embeds=embeds, cfg=cfg)
    else:
        tokens = jax.random.randint(KEY, (B, S), 0, arch.vocab)
        ref_logits, _ = forward(arch, params, tokens=tokens, cfg=cfg)

    cache = init_cache(arch, B, S + 4, cfg)
    outs = []
    for t in range(S):
        if arch.embeds_input:
            lg, cache = decode_step(arch, params, cache, embeds=embeds[:, t],
                                    pos=jnp.int32(t), cfg=cfg)
        else:
            lg, cache = decode_step(arch, params, cache, tokens=tokens[:, t],
                                    pos=jnp.int32(t), cfg=cfg)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_hubert_encoder_no_decode():
    arch = get_config("hubert-xlarge")
    ok, reason = shape_applicable(arch, SHAPES["decode_32k"])
    assert not ok and "encoder" in reason


def test_long_500k_applicability():
    assert shape_applicable(get_config("mamba2-2.7b"), SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("hymba-1.5b"), SHAPES["long_500k"])[0]
    for name in ("yi-6b", "nemotron-4-340b", "dbrx-132b", "llava-next-34b"):
        ok, reason = shape_applicable(get_config(name), SHAPES["long_500k"])
        assert not ok and "sub-quadratic" in reason


def test_param_count_estimates_match_init():
    for name in sorted(ARCHS):
        arch = scale_arch(get_config(name), "tiny")
        params = init_params(arch, KEY, CFG)
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = arch.param_count()
        assert abs(actual - est) / actual < 0.12, (name, actual, est)


def test_moe_load_stats_exposed():
    arch = scale_arch(get_config("granite-moe-3b-a800m"), "tiny")
    params = init_params(arch, KEY, CFG)
    batch = _batch(arch)
    loss, metrics = loss_fn(arch, params, batch, CFG)
    assert "moe_drop" in metrics
    assert 0.0 <= float(metrics["moe_drop"]) <= 1.0


def test_sliding_window_matches_full_when_window_covers():
    arch = scale_arch(get_config("hymba-1.5b"), "tiny")
    big_window = dataclasses.replace(arch, window=64)   # covers S=32
    params = init_params(big_window, KEY, CFG)
    batch = _batch(big_window)
    lg_w, _ = forward(big_window, params, tokens=batch["tokens"], cfg=CFG)
    full = dataclasses.replace(big_window, window=0)
    lg_f, _ = forward(full, params, tokens=batch["tokens"], cfg=CFG)
    np.testing.assert_allclose(np.asarray(lg_w), np.asarray(lg_f),
                               rtol=1e-4, atol=1e-4)
