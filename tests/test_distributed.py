"""Distributed-runtime tests on an 8-device host mesh.

These run in a subprocess so the 8-device XLA_FLAGS override never leaks
into other tests (the suite must see 1 device)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    # jax.sharding.AxisType landed after 0.4.x; older JAX meshes are
    # implicitly Auto, so just drop the kwarg there.
    try:
        from jax.sharding import AxisType
        def make_mesh(shape, names):
            return jax.make_mesh(shape, names,
                                 axis_types=(AxisType.Auto,) * len(names))
    except ImportError:
        def make_mesh(shape, names):
            return jax.make_mesh(shape, names)

    from repro.configs import get_config
    from repro.launch.train import scale_arch
    from repro.models import RunCfg, init_params
    from repro.train.optim import init_opt_state
    from repro.train.step import TrainCfg, make_train_step
    from repro.train.fault_tolerance import elastic_reshard
    from repro.parallel.compression import compressed_psum
    from repro.parallel.pipeline import pipeline_apply

    out = {}
    mesh = make_mesh((2, 4), ("data", "model"))

    # 1) sharded train step matches single-device numerics
    arch = scale_arch(get_config("yi-6b"), "tiny")
    cfg = TrainCfg(run=RunCfg(q_chunk=0, remat=False), num_microbatches=2)
    key = jax.random.PRNGKey(0)
    params = init_params(arch, key, cfg.run)
    opt = init_opt_state(cfg.opt, params)
    batch = {
        "tokens": jax.random.randint(key, (2, 4, 32), 0, arch.vocab),
        "labels": jax.random.randint(key, (2, 4, 32), 0, arch.vocab),
    }
    step_single = make_train_step(arch, cfg, mesh=None)
    p1, o1, m1 = step_single(params, opt, batch)
    step_sharded = make_train_step(arch, cfg, mesh)
    jitted = step_sharded.jit_with(
        jax.eval_shape(lambda: init_params(arch, key, cfg.run)), batch)
    params2 = init_params(arch, key, cfg.run)
    opt2 = init_opt_state(cfg.opt, params2)
    p2, o2, m2 = jitted(params2, opt2, batch)
    out["loss_single"] = float(m1["loss"])
    out["loss_sharded"] = float(m2["loss"])
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    out["param_max_diff"] = diff

    # 2) elastic reshard onto a smaller mesh
    small = make_mesh((2, 2), ("data", "model"))
    state = elastic_reshard({"params": p2, "opt_state": o2}, arch, small)
    d2 = max(float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
             for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(state["params"])))
    out["reshard_diff"] = d2

    # 3) compressed psum ~= exact psum
    pod_mesh = make_mesh((8,), ("pod",))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
    exact = shard_map(lambda v: jax.lax.psum(v, "pod"), mesh=pod_mesh,
                      in_specs=P("pod"), out_specs=P("pod"))(x)
    comp = shard_map(lambda v: compressed_psum(v, "pod"), mesh=pod_mesh,
                     in_specs=P("pod"), out_specs=P("pod"))(x)
    rel = float(jnp.linalg.norm(comp - exact) / jnp.linalg.norm(exact))
    out["psum_rel_err"] = rel

    # 4) shard_map GPipe pipeline == sequential stage application
    S, G, B, H = 4, 6, 2, 16
    stage_mesh = make_mesh((4,), ("pod",))
    ks = jax.random.split(jax.random.PRNGKey(2), S)
    stage_w = jnp.stack([jax.random.normal(k, (H, H)) / jnp.sqrt(H) for k in ks])
    mbs = jax.random.normal(jax.random.PRNGKey(3), (G, B, H))
    stage_fn = lambda w, x: jnp.tanh(x @ w)
    piped = pipeline_apply(stage_fn, stage_w, mbs, stage_mesh, axis="pod")
    ref = mbs
    for s in range(S):
        ref = jnp.tanh(ref @ stage_w[s])
    out["pipe_diff"] = float(jnp.max(jnp.abs(piped - ref)))

    # 5) pipeline is differentiable (grads flow through ppermute)
    def loss(w):
        y = pipeline_apply(stage_fn, w, mbs, stage_mesh, axis="pod")
        return jnp.sum(y ** 2)
    g = jax.grad(loss)(stage_w)
    out["pipe_grad_norm"] = float(jnp.linalg.norm(g))

    # 6) shard_map expert-parallel MoE == single-device MoE (drop-free)
    from repro.models.layers import moe, moe_ep
    T, Hm, E, F, kk = 256, 32, 10, 16, 4
    kmoe = jax.random.split(jax.random.PRNGKey(4), 5)
    mparams = {"router": jax.random.normal(kmoe[0], (Hm, E)) * 0.1,
               "wg": jax.random.normal(kmoe[1], (E, Hm, F)) * 0.1,
               "wi": jax.random.normal(kmoe[2], (E, Hm, F)) * 0.1,
               "wo": jax.random.normal(kmoe[3], (E, F, Hm)) * 0.1}
    xm = jax.random.normal(kmoe[4], (T, Hm))
    ref, _ = moe(xm, mparams, top_k=kk, capacity_factor=16.0)
    got, _ = jax.jit(lambda x, p: moe_ep(x, p, kk, mesh,
                                         capacity_factor=16.0))(xm, mparams)
    out["moe_ep_diff"] = float(jnp.max(jnp.abs(got - ref)))

    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True,
                          env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"},
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_step_matches_single_device(results):
    assert results["loss_single"] == pytest.approx(results["loss_sharded"], rel=1e-3)
    assert results["param_max_diff"] < 5e-2     # bf16 compute tolerance


def test_elastic_reshard_preserves_values(results):
    assert results["reshard_diff"] == 0.0


def test_compressed_psum_close_to_exact(results):
    assert results["psum_rel_err"] < 0.01


def test_pipeline_matches_sequential(results):
    assert results["pipe_diff"] < 1e-5


def test_pipeline_differentiable(results):
    assert results["pipe_grad_norm"] > 0


def test_moe_ep_matches_reference(results):
    assert results["moe_ep_diff"] < 1e-4
