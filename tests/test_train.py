"""Training substrate: optimizer math, data determinism, checkpointing,
end-to-end loss decrease."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.train import scale_arch, train_loop
from repro.models import RunCfg, init_params
from repro.train.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    restore_latest,
    save_checkpoint,
)
from repro.train.data import DataCfg, PrefetchIterator, SyntheticDataset
from repro.train.optim import OptimizerCfg, apply_optimizer, init_opt_state, lr_at
from repro.train.step import TrainCfg, init_train_state, make_train_step
from proptools import given


# ----------------------------------------------------------------- optimizer

def test_adam_matches_reference_implementation():
    cfg = OptimizerCfg(peak_lr=1e-2, warmup_steps=0, decay_steps=100,
                       weight_decay=0.0, grad_clip=0.0, min_lr_ratio=1.0)
    params = {"w": jnp.array([[1.0, 2.0]])}
    grads = {"w": jnp.array([[0.1, -0.2]])}
    state = init_opt_state(cfg, params)
    new_params, new_state, _ = apply_optimizer(cfg, params, grads, state)
    # hand-computed Adam step 1: m=0.1g, v=0.05g^2, mhat=g, vhat=g^2
    g = np.array([[0.1, -0.2]])
    expected = np.array([[1.0, 2.0]]) - 1e-2 * g / (np.abs(g) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), expected, rtol=1e-5)


def test_grad_clip_scales_update():
    cfg = OptimizerCfg(peak_lr=1e-2, warmup_steps=0, grad_clip=0.1,
                       weight_decay=0.0, min_lr_ratio=1.0, name="sgd")
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 10.0)}   # norm 20 >> clip 0.1
    state = init_opt_state(cfg, params)
    new_params, _, m = apply_optimizer(cfg, params, grads, state)
    delta = np.asarray(params["w"] - new_params["w"])
    assert np.linalg.norm(delta / 1e-2) == pytest.approx(0.1, rel=1e-4)


def test_lr_schedule_warmup_and_decay():
    cfg = OptimizerCfg(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                       min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(cfg, jnp.int32(110))) == pytest.approx(0.1, rel=1e-3)


def test_bf16_moments_policy():
    cfg = OptimizerCfg(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((8, 8))}
    state = init_opt_state(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16


# ----------------------------------------------------------------- data

def test_data_deterministic_and_resumable():
    arch = scale_arch(get_config("yi-6b"), "tiny")
    cfg = DataCfg(seq_len=16, global_batch=4, num_microbatches=2, seed=3)
    ds = SyntheticDataset(arch, cfg)
    b1 = ds.batch_at(7)
    b2 = ds.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = PrefetchIterator(ds, start_step=7)
    b3 = next(it)
    it.close()
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])


def test_data_labels_are_shifted_tokens():
    arch = scale_arch(get_config("yi-6b"), "tiny")
    ds = SyntheticDataset(arch, DataCfg(seq_len=16, global_batch=2))
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b["labels"][..., :-1], b["tokens"][..., 1:])


# ----------------------------------------------------------------- ckpt

def test_checkpoint_roundtrip(tmp_path):
    arch = scale_arch(get_config("yi-6b"), "tiny")
    cfg = TrainCfg(run=RunCfg(q_chunk=0, remat=False))
    params, opt_state = init_train_state(arch, cfg, jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 5, {"params": params, "opt_state": opt_state},
                    extra={"data_step": 5})
    assert latest_step(tmp_path) == 5
    state, extra = restore_checkpoint(tmp_path, 5,
                                      {"params": params, "opt_state": opt_state})
    assert extra["data_step"] == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune_and_latest(tmp_path):
    tree = {"x": jnp.arange(4)}
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, step, {"t": tree}, keep_last=2)
    assert latest_step(tmp_path) == 5
    got, state, _ = restore_latest(tmp_path, {"t": tree})
    assert got == 5


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(tmp_path, every_steps=2, keep_last=2)
    tree = {"x": jnp.arange(3)}
    assert not mgr.maybe_save(1, {"t": tree})
    assert mgr.maybe_save(2, {"t": tree})
    mgr.wait()
    assert latest_step(tmp_path) == 2


# ----------------------------------------------------------------- end2end

def test_train_loop_loss_decreases(tmp_path):
    arch = scale_arch(get_config("yi-6b"), "tiny")
    cfg = TrainCfg(run=RunCfg(q_chunk=0, remat=False),
                   opt=OptimizerCfg(peak_lr=1e-3, warmup_steps=5, decay_steps=40),
                   num_microbatches=2)
    data_cfg = DataCfg(seq_len=64, global_batch=8, num_microbatches=2)
    _, _, losses = train_loop(arch, cfg, data_cfg, steps=40, log_every=100,
                              log_fn=lambda *_: None)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_restart_resumes_deterministically(tmp_path):
    arch = scale_arch(get_config("yi-6b"), "tiny")
    cfg = TrainCfg(run=RunCfg(q_chunk=0, remat=False),
                   opt=OptimizerCfg(peak_lr=1e-3, warmup_steps=2, decay_steps=20),
                   num_microbatches=1)
    data_cfg = DataCfg(seq_len=32, global_batch=4, num_microbatches=1)
    # continuous run
    _, _, losses_full = train_loop(arch, cfg, data_cfg, steps=12,
                                   log_every=100, log_fn=lambda *_: None)
    # interrupted run: 6 steps, checkpoint, then resume to 12
    ck = tmp_path / "ck"
    train_loop(arch, cfg, data_cfg, steps=6, ckpt_dir=ck, ckpt_every=3,
               log_every=100, log_fn=lambda *_: None)
    _, _, losses_resumed = train_loop(arch, cfg, data_cfg, steps=12,
                                      ckpt_dir=ck, ckpt_every=3,
                                      log_every=100, log_fn=lambda *_: None)
    # the resumed tail must match the continuous run's tail
    np.testing.assert_allclose(losses_resumed[-3:], losses_full[-3:],
                               rtol=2e-4, atol=2e-4)
