"""Unified Experiment API: typed enums, validation, report round-trip,
sweep-engine parity (serial vs process pool vs legacy sweep_plans),
hardware x parallelism search."""

import warnings

import pytest

from repro.api import (
    BoundaryMode,
    Experiment,
    HardwareSearchSpace,
    Layout,
    NoCMode,
    ParallelPlan,
    RunReport,
    Schedule,
    SearchSpace,
    SweepEngine,
    SweepReport,
    resolve_hardware,
)
from repro.core import simulate, sweep_plans, transformer_lm_graph, tpu_v5e_pod


# ---------------------------------------------------------------------------
# typed enums (the legacy case-insensitive coercion path is gone: members
# and their exact canonical values construct silently, anything else raises)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls,raw,member", [
    (Schedule, "1f1b", Schedule.ONE_F_ONE_B),
    (Schedule, "gpipe", Schedule.GPIPE),
    (Layout, "s_shape", Layout.S_SHAPE),
    (Layout, "line", Layout.LINE),
    (NoCMode, "macro", NoCMode.MACRO),
    (BoundaryMode, "strategy", BoundaryMode.STRATEGY),
])
def test_enum_constructs_from_canonical_value_silently(cls, raw, member):
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # no DeprecationWarning anymore
        assert cls(raw) is member
        assert cls(member) is member


@pytest.mark.parametrize("bad", ["one_f_one_b", "GPIPE", "2f2b", ""])
def test_enum_rejects_non_canonical_strings(bad):
    with pytest.raises(ValueError, match="unknown Schedule"):
        Schedule(bad)


def test_parallel_plan_is_strictly_typed():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = ParallelPlan(schedule=Schedule.GPIPE, layout=Layout.LINE)
    assert plan.schedule is Schedule.GPIPE
    assert plan.layout is Layout.LINE
    # str-subclass enums keep value comparisons working
    assert plan.schedule == "gpipe"


def test_simulate_accepts_canonical_mode_without_warning():
    g = transformer_lm_graph("t", 2, 128, 4, seq_len=64, batch=1, vocab=256)
    hw = tpu_v5e_pod(2, 2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res = simulate(g, hw, ParallelPlan(global_batch=2), noc_mode="macro")
    assert res.throughput > 0


def test_unknown_schedule_string_raises_in_plan():
    with pytest.raises(ValueError, match="unknown Schedule"):
        ParallelPlan(schedule="2f2b")


# ---------------------------------------------------------------------------
# Experiment validation
# ---------------------------------------------------------------------------

def test_experiment_requires_plan_or_search():
    with pytest.raises(ValueError, match="plan.*or.*search"):
        Experiment(arch="yi-6b")
    with pytest.raises(ValueError, match="not both"):
        Experiment(arch="yi-6b", plan=ParallelPlan(),
                   search=SearchSpace())


def test_experiment_rejects_bad_factorization():
    hw = tpu_v5e_pod(2, 2)      # 4 devices
    with pytest.raises(ValueError, match="needs 8 devices"):
        Experiment(arch="yi-6b", hardware=hw,
                   plan=ParallelPlan(pp=2, dp=2, tp=2, global_batch=4))


def test_experiment_rejects_bad_batch_split():
    hw = tpu_v5e_pod(2, 2)
    with pytest.raises(ValueError, match="not divisible"):
        Experiment(arch="yi-6b", hardware=hw,
                   plan=ParallelPlan(pp=1, dp=2, tp=2, microbatch=2,
                                     global_batch=6))


def test_experiment_rejects_unknown_names():
    with pytest.raises(KeyError, match="unknown arch"):
        Experiment(arch="not-a-model", plan=ParallelPlan())
    with pytest.raises(ValueError, match="unknown hardware preset"):
        Experiment(arch="yi-6b", hardware="cerebras-42", plan=ParallelPlan())


def test_search_space_rejects_oversubscribed_degrees():
    hw = tpu_v5e_pod(2, 2)
    space = SearchSpace(degrees=[(2, 2, 2)])
    with pytest.raises(ValueError, match="needs 8"):
        space.enumerate_plans(hw, global_batch=8)


def test_resolve_hardware_presets():
    assert resolve_hardware("grayskull").name == "grayskull"
    assert resolve_hardware("a100x16").num_devices == 16
    assert resolve_hardware("tpu_v5e_2x2").num_devices == 4


# ---------------------------------------------------------------------------
# report JSON round-trip
# ---------------------------------------------------------------------------

def _tiny_experiment(**kw):
    defaults = dict(
        arch="yi-6b",
        hardware=tpu_v5e_pod(2, 2),
        seq_len=128,
        global_batch=8,
    )
    defaults.update(kw)
    return Experiment(**defaults)


def test_run_report_json_round_trip():
    exp = _tiny_experiment(plan=ParallelPlan(pp=2, dp=2, tp=1, global_batch=8))
    rep = exp.run()
    back = RunReport.from_json(rep.to_json())
    assert back == rep
    assert isinstance(back.plan, ParallelPlan)
    assert back.plan.schedule is Schedule.ONE_F_ONE_B
    assert back.throughput == rep.throughput


def test_sweep_report_json_round_trip():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=4, microbatch_sizes=(1,), layouts=(Layout.S_SHAPE,)))
    rep = exp.sweep()
    assert rep.runs
    back = SweepReport.from_json(rep.to_json())
    assert back == rep


# ---------------------------------------------------------------------------
# sweep engine: parity + pruning
# ---------------------------------------------------------------------------

def test_sweep_engine_serial_matches_process_pool():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=6, microbatch_sizes=(1, 2)))
    serial = exp.sweep(workers=0)
    pooled = exp.sweep(workers=2)
    assert serial.runs, "sweep produced no feasible plans"
    assert [r.plan for r in serial.runs] == [r.plan for r in pooled.runs]
    assert [r.throughput for r in serial.runs] == \
           [r.throughput for r in pooled.runs]
    assert pooled.executor.startswith("process")


def test_sweep_engine_matches_legacy_sweep_plans():
    """Acceptance: a >= 24-plan search space ranked by the process-pool
    SweepEngine reproduces the legacy serial sweep_plans ranking."""
    exp = _tiny_experiment(
        global_batch=16,
        search=SearchSpace(max_plans=48, microbatch_sizes=(1, 2, 4),
                           tp_contiguous=(True, False)))
    plans = exp.search.enumerate_plans(exp.hardware_spec, exp.global_batch,
                                       training=True, arch=exp.arch_config)
    assert len(plans) >= 24
    legacy = sweep_plans(exp.build_graph, exp.hardware_spec, plans,
                         noc_mode=NoCMode.MACRO)
    engine = SweepEngine(workers=2).sweep(exp, plans)
    assert engine.executor.startswith("process")
    assert [r.plan for r in legacy] == [r.plan for r in engine.runs]
    assert [r.throughput for r in legacy] == \
           pytest.approx([r.throughput for r in engine.runs])


def test_memory_cap_prunes_before_simulation():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=6, microbatch_sizes=(1, 2)))
    base = exp.sweep()
    mems = sorted(r.peak_memory_bytes for r in base.runs)
    cap = mems[len(mems) // 2]          # prune the top half
    capped = exp.with_(memory_cap=cap).sweep()
    assert capped.num_pruned_memory > 0
    assert all(r.peak_memory_bytes <= cap for r in capped.runs)
    # parity with the legacy post-hoc filter: same surviving ranking
    expect = [r.plan for r in base.runs if r.peak_memory_bytes <= cap]
    assert [r.plan for r in capped.runs] == expect


def test_graph_builder_experiments_sweep_serially():
    exp = Experiment(
        graph_builder=lambda p: transformer_lm_graph(
            "t", 2, 128, 4, seq_len=64, batch=p.microbatch * p.dp, vocab=256),
        hardware=tpu_v5e_pod(2, 2),
        search=SearchSpace(max_plans=3, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        global_batch=4,
    )
    with pytest.warns(RuntimeWarning, match="not picklable"):
        rep = exp.sweep(workers=2)     # lambda builder -> serial fallback
    assert rep.runs and rep.executor == "serial"


# ---------------------------------------------------------------------------
# extended SearchSpace axes: interleave / zero / comm_strategy
# ---------------------------------------------------------------------------

def test_search_space_sweeps_interleave_zero_and_comm_strategy():
    hw = tpu_v5e_pod(2, 2)
    space = SearchSpace(degrees=[(2, 2, 1)], microbatch_sizes=(1,),
                        layouts=(Layout.S_SHAPE,),
                        interleave=(1, 2), zero_stages=(0, 2),
                        comm_strategies=(1, 2), max_plans=64)
    plans = space.enumerate_plans(hw, global_batch=8)
    assert {p.interleave for p in plans} == {1, 2}
    assert {p.zero for p in plans} == {0, 2}
    assert {p.comm_strategy for p in plans} == {1, 2}
    assert len(plans) == 2 * 2 * 2


def test_search_space_interleave_needs_pipeline_and_respects_layers():
    hw = tpu_v5e_pod(2, 2)
    space = SearchSpace(degrees=[(1, 4, 1)], microbatch_sizes=(1,),
                        layouts=(Layout.S_SHAPE,), interleave=(1, 2))
    plans = space.enumerate_plans(hw, global_batch=8)
    assert {p.interleave for p in plans} == {1}     # pp=1 can't interleave


def test_search_space_rejects_bad_new_axes():
    with pytest.raises(ValueError, match="zero_stages"):
        SearchSpace(zero_stages=(4,))
    with pytest.raises(ValueError, match="comm_strategies"):
        SearchSpace(comm_strategies=(3,))
    with pytest.raises(ValueError, match="interleave"):
        SearchSpace(interleave=(0,))


def test_extended_axes_pruning_parity_serial_vs_pooled():
    """Satellite acceptance: memory-cap pruning over the new axes ranks
    identically through the serial and process-pool engines."""
    exp = _tiny_experiment(
        global_batch=16,
        search=SearchSpace(degrees=[(2, 2, 1), (2, 1, 2)],
                           microbatch_sizes=(1, 2), interleave=(1, 2),
                           zero_stages=(0, 1), max_plans=64))
    base = exp.sweep(workers=0)
    assert {r.plan.interleave for r in base.runs} >= {1, 2}
    assert {r.plan.zero for r in base.runs} >= {0, 1}
    mems = sorted(r.peak_memory_bytes for r in base.runs)
    cap = mems[len(mems) // 2]
    serial = exp.with_(memory_cap=cap).sweep(workers=0)
    pooled = exp.with_(memory_cap=cap).sweep(workers=2)
    assert serial.num_pruned_memory > 0
    assert pooled.executor.startswith("process")
    assert serial.num_pruned_memory == pooled.num_pruned_memory
    assert [r.plan for r in serial.runs] == [r.plan for r in pooled.runs]
    assert [r.throughput for r in serial.runs] == \
           [r.throughput for r in pooled.runs]


# ---------------------------------------------------------------------------
# hardware x parallelism search
# ---------------------------------------------------------------------------

def test_hardware_search_space_enumerates_variants():
    base = tpu_v5e_pod(2, 2)
    space = HardwareSearchSpace(tile_flops=(100e12, 197e12),
                                intra_bw=(25e9, 50e9))
    specs = space.enumerate_specs(base)
    assert len(specs) == 4
    assert len({s.name for s in specs}) == 4         # distinct variant names
    assert {s.tile.flops for s in specs} == {100e12, 197e12}
    assert all(s.to_dict() for s in specs)           # all serializable


def test_hardware_search_mesh_shape_replaces_ports():
    from repro.core import grayskull
    base = grayskull()                               # 8 ports on row 0
    space = HardwareSearchSpace(mesh_shapes=((6, 6),))
    (spec,) = space.enumerate_specs(base)
    assert spec.num_devices == 36
    assert len(spec.dram_ports) == min(8, 6)         # re-placed on west edge
    assert all(p < 36 for p in spec.dram_ports)


def test_experiment_sweeps_hardware_cross_parallelism():
    exp = _tiny_experiment(
        search=SearchSpace(max_plans=3, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12)))
    rep = exp.sweep()
    assert rep.num_hardware == 2
    assert len({r.hardware for r in rep.runs}) == 2
    thpts = [r.throughput for r in rep.runs]
    assert thpts == sorted(thpts, reverse=True)      # merged ranking
    # faster tiles win: best point comes from the higher-flops variant
    assert "197T" in rep.best.hardware
    back = SweepReport.from_json(rep.to_json())      # num_hardware round-trips
    assert back.num_hardware == 2 and back == rep


def test_resolve_hardware_d_model_calibration():
    lo = resolve_hardware("a100x8", d_model=4096)
    hi = resolve_hardware("a100x8", d_model=20480)
    assert hi.tile.compute_efficiency > lo.tile.compute_efficiency
    with pytest.raises(ValueError, match="a100x<N>"):
        resolve_hardware("wafer_scale", d_model=4096)


def test_hardware_search_rejects_undivisible_mesh_shape():
    from repro.api import MeshSpec, HardwareSpec
    from repro.core import DRAMSpec, TileSpec
    base = HardwareSpec(name="t",
                        topology=MeshSpec(8, 8, intra_bw=1e12, inter_bw=2.5e11,
                                          tile_shape=(4, 4)),
                        tile=TileSpec(flops=1e12, sram_bytes=1e6),
                        dram=DRAMSpec(bandwidth=1e11))
    with pytest.raises(ValueError, match="does not divide"):
        HardwareSearchSpace(mesh_shapes=((5, 5),)).enumerate_specs(base)


def test_hardware_search_counts_oversubscribed_variants_as_failed():
    """A variant too small for explicit search degrees must not abort the
    whole hardware sweep."""
    exp = _tiny_experiment(          # base tpu_v5e_2x2 has 4 devices
        search=SearchSpace(degrees=[(2, 2, 1)], microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        hardware_search=HardwareSearchSpace(mesh_shapes=((2, 2), (1, 2))))
    rep = exp.sweep()
    assert rep.num_hardware == 2
    assert rep.num_failed == 1               # the 1x2 variant (2 devices)
    assert rep.runs and all("2x2" in r.hardware for r in rep.runs)
