"""Unified Experiment API: typed enums, validation, report round-trip,
sweep-engine parity (serial vs process pool vs legacy sweep_plans),
hardware x parallelism search."""

import warnings

import pytest

from repro.api import (
    BoundaryMode,
    Experiment,
    HardwareSearchSpace,
    Layout,
    NoCMode,
    ParallelPlan,
    RunReport,
    Schedule,
    SearchSpace,
    SweepEngine,
    SweepReport,
    resolve_hardware,
)
from repro.core import simulate, sweep_plans, transformer_lm_graph, tpu_v5e_pod


# ---------------------------------------------------------------------------
# typed enums (the legacy case-insensitive coercion path is gone: members
# and their exact canonical values construct silently, anything else raises)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls,raw,member", [
    (Schedule, "1f1b", Schedule.ONE_F_ONE_B),
    (Schedule, "gpipe", Schedule.GPIPE),
    (Layout, "s_shape", Layout.S_SHAPE),
    (Layout, "line", Layout.LINE),
    (NoCMode, "macro", NoCMode.MACRO),
    (BoundaryMode, "strategy", BoundaryMode.STRATEGY),
])
def test_enum_constructs_from_canonical_value_silently(cls, raw, member):
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # no DeprecationWarning anymore
        assert cls(raw) is member
        assert cls(member) is member


@pytest.mark.parametrize("bad", ["one_f_one_b", "GPIPE", "2f2b", ""])
def test_enum_rejects_non_canonical_strings(bad):
    with pytest.raises(ValueError, match="unknown Schedule"):
        Schedule(bad)


def test_parallel_plan_is_strictly_typed():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = ParallelPlan(schedule=Schedule.GPIPE, layout=Layout.LINE)
    assert plan.schedule is Schedule.GPIPE
    assert plan.layout is Layout.LINE
    # str-subclass enums keep value comparisons working
    assert plan.schedule == "gpipe"


def test_simulate_accepts_canonical_mode_without_warning():
    g = transformer_lm_graph("t", 2, 128, 4, seq_len=64, batch=1, vocab=256)
    hw = tpu_v5e_pod(2, 2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res = simulate(g, hw, ParallelPlan(global_batch=2), noc_mode="macro")
    assert res.throughput > 0


def test_unknown_schedule_string_raises_in_plan():
    with pytest.raises(ValueError, match="unknown Schedule"):
        ParallelPlan(schedule="2f2b")


# ---------------------------------------------------------------------------
# Experiment validation
# ---------------------------------------------------------------------------

def test_experiment_requires_plan_or_search():
    with pytest.raises(ValueError, match="plan.*or.*search"):
        Experiment(arch="yi-6b")
    with pytest.raises(ValueError, match="not both"):
        Experiment(arch="yi-6b", plan=ParallelPlan(),
                   search=SearchSpace())


def test_experiment_rejects_bad_factorization():
    hw = tpu_v5e_pod(2, 2)      # 4 devices
    with pytest.raises(ValueError, match="needs 8 devices"):
        Experiment(arch="yi-6b", hardware=hw,
                   plan=ParallelPlan(pp=2, dp=2, tp=2, global_batch=4))


def test_experiment_rejects_bad_batch_split():
    hw = tpu_v5e_pod(2, 2)
    with pytest.raises(ValueError, match="not divisible"):
        Experiment(arch="yi-6b", hardware=hw,
                   plan=ParallelPlan(pp=1, dp=2, tp=2, microbatch=2,
                                     global_batch=6))


def test_experiment_rejects_unknown_names():
    with pytest.raises(KeyError, match="unknown arch"):
        Experiment(arch="not-a-model", plan=ParallelPlan())
    with pytest.raises(ValueError, match="unknown hardware preset"):
        Experiment(arch="yi-6b", hardware="cerebras-42", plan=ParallelPlan())


def test_search_space_rejects_oversubscribed_degrees():
    hw = tpu_v5e_pod(2, 2)
    space = SearchSpace(degrees=[(2, 2, 2)])
    with pytest.raises(ValueError, match="needs 8"):
        space.enumerate_plans(hw, global_batch=8)


def test_resolve_hardware_presets():
    assert resolve_hardware("grayskull").name == "grayskull"
    assert resolve_hardware("a100x16").num_devices == 16
    assert resolve_hardware("tpu_v5e_2x2").num_devices == 4


# ---------------------------------------------------------------------------
# report JSON round-trip
# ---------------------------------------------------------------------------

def _tiny_experiment(**kw):
    defaults = dict(
        arch="yi-6b",
        hardware=tpu_v5e_pod(2, 2),
        seq_len=128,
        global_batch=8,
    )
    defaults.update(kw)
    return Experiment(**defaults)


def test_run_report_json_round_trip():
    exp = _tiny_experiment(plan=ParallelPlan(pp=2, dp=2, tp=1, global_batch=8))
    rep = exp.run()
    back = RunReport.from_json(rep.to_json())
    assert back == rep
    assert isinstance(back.plan, ParallelPlan)
    assert back.plan.schedule is Schedule.ONE_F_ONE_B
    assert back.throughput == rep.throughput


def test_sweep_report_json_round_trip():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=4, microbatch_sizes=(1,), layouts=(Layout.S_SHAPE,)))
    rep = exp.sweep()
    assert rep.runs
    back = SweepReport.from_json(rep.to_json())
    assert back == rep


# ---------------------------------------------------------------------------
# sweep engine: parity + pruning
# ---------------------------------------------------------------------------

def test_sweep_engine_serial_matches_process_pool():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=6, microbatch_sizes=(1, 2)))
    serial = exp.sweep(workers=0)
    pooled = exp.sweep(workers=2)
    assert serial.runs, "sweep produced no feasible plans"
    assert [r.plan for r in serial.runs] == [r.plan for r in pooled.runs]
    assert [r.throughput for r in serial.runs] == \
           [r.throughput for r in pooled.runs]
    assert pooled.executor.startswith("process")


def test_sweep_engine_matches_legacy_sweep_plans():
    """Acceptance: a >= 24-plan search space ranked by the process-pool
    SweepEngine reproduces the legacy serial sweep_plans ranking."""
    exp = _tiny_experiment(
        global_batch=16,
        search=SearchSpace(max_plans=48, microbatch_sizes=(1, 2, 4),
                           tp_contiguous=(True, False)))
    plans = exp.search.enumerate_plans(exp.hardware_spec, exp.global_batch,
                                       training=True, arch=exp.arch_config)
    assert len(plans) >= 24
    legacy = sweep_plans(exp.build_graph, exp.hardware_spec, plans,
                         noc_mode=NoCMode.MACRO)
    engine = SweepEngine(workers=2).sweep(exp, plans)
    assert engine.executor.startswith("process")
    assert [r.plan for r in legacy] == [r.plan for r in engine.runs]
    assert [r.throughput for r in legacy] == \
           pytest.approx([r.throughput for r in engine.runs])


def test_memory_cap_prunes_before_simulation():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=6, microbatch_sizes=(1, 2)))
    base = exp.sweep()
    mems = sorted(r.peak_memory_bytes for r in base.runs)
    cap = mems[len(mems) // 2]          # prune the top half
    capped = exp.with_(memory_cap=cap).sweep()
    assert capped.num_pruned_memory > 0
    assert all(r.peak_memory_bytes <= cap for r in capped.runs)
    # parity with the legacy post-hoc filter: same surviving ranking
    expect = [r.plan for r in base.runs if r.peak_memory_bytes <= cap]
    assert [r.plan for r in capped.runs] == expect


def test_graph_builder_experiments_sweep_serially():
    exp = Experiment(
        graph_builder=lambda p: transformer_lm_graph(
            "t", 2, 128, 4, seq_len=64, batch=p.microbatch * p.dp, vocab=256),
        hardware=tpu_v5e_pod(2, 2),
        search=SearchSpace(max_plans=3, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        global_batch=4,
    )
    with pytest.warns(RuntimeWarning, match="not picklable"):
        rep = exp.sweep(workers=2)     # lambda builder -> serial fallback
    assert rep.runs and rep.executor == "serial"


# ---------------------------------------------------------------------------
# extended SearchSpace axes: interleave / zero / comm_strategy
# ---------------------------------------------------------------------------

def test_search_space_sweeps_interleave_zero_and_comm_strategy():
    hw = tpu_v5e_pod(2, 2)
    space = SearchSpace(degrees=[(2, 2, 1)], microbatch_sizes=(1,),
                        layouts=(Layout.S_SHAPE,),
                        interleave=(1, 2), zero_stages=(0, 2),
                        comm_strategies=(1, 2), max_plans=64)
    plans = space.enumerate_plans(hw, global_batch=8)
    assert {p.interleave for p in plans} == {1, 2}
    assert {p.zero for p in plans} == {0, 2}
    assert {p.comm_strategy for p in plans} == {1, 2}
    assert len(plans) == 2 * 2 * 2


def test_search_space_interleave_needs_pipeline_and_respects_layers():
    hw = tpu_v5e_pod(2, 2)
    space = SearchSpace(degrees=[(1, 4, 1)], microbatch_sizes=(1,),
                        layouts=(Layout.S_SHAPE,), interleave=(1, 2))
    plans = space.enumerate_plans(hw, global_batch=8)
    assert {p.interleave for p in plans} == {1}     # pp=1 can't interleave


def test_search_space_rejects_bad_new_axes():
    with pytest.raises(ValueError, match="zero_stages"):
        SearchSpace(zero_stages=(4,))
    with pytest.raises(ValueError, match="comm_strategies"):
        SearchSpace(comm_strategies=(3,))
    with pytest.raises(ValueError, match="interleave"):
        SearchSpace(interleave=(0,))


def test_extended_axes_pruning_parity_serial_vs_pooled():
    """Satellite acceptance: memory-cap pruning over the new axes ranks
    identically through the serial and process-pool engines."""
    exp = _tiny_experiment(
        global_batch=16,
        search=SearchSpace(degrees=[(2, 2, 1), (2, 1, 2)],
                           microbatch_sizes=(1, 2), interleave=(1, 2),
                           zero_stages=(0, 1), max_plans=64))
    base = exp.sweep(workers=0)
    assert {r.plan.interleave for r in base.runs} >= {1, 2}
    assert {r.plan.zero for r in base.runs} >= {0, 1}
    mems = sorted(r.peak_memory_bytes for r in base.runs)
    cap = mems[len(mems) // 2]
    serial = exp.with_(memory_cap=cap).sweep(workers=0)
    pooled = exp.with_(memory_cap=cap).sweep(workers=2)
    assert serial.num_pruned_memory > 0
    assert pooled.executor.startswith("process")
    assert serial.num_pruned_memory == pooled.num_pruned_memory
    assert [r.plan for r in serial.runs] == [r.plan for r in pooled.runs]
    assert [r.throughput for r in serial.runs] == \
           [r.throughput for r in pooled.runs]


# ---------------------------------------------------------------------------
# hardware x parallelism search
# ---------------------------------------------------------------------------

def test_hardware_search_space_enumerates_variants():
    base = tpu_v5e_pod(2, 2)
    space = HardwareSearchSpace(tile_flops=(100e12, 197e12),
                                intra_bw=(25e9, 50e9))
    specs = space.enumerate_specs(base)
    assert len(specs) == 4
    assert len({s.name for s in specs}) == 4         # distinct variant names
    assert {s.tile.flops for s in specs} == {100e12, 197e12}
    assert all(s.to_dict() for s in specs)           # all serializable


def test_hardware_search_mesh_shape_replaces_ports():
    from repro.core import grayskull
    base = grayskull()                               # 6 ports on row 0 (north)
    space = HardwareSearchSpace(mesh_shapes=((6, 6),))
    (spec,) = space.enumerate_specs(base)
    assert spec.num_devices == 36
    assert len(spec.dram_ports) == 6                 # port count preserved
    assert all(p < 36 for p in spec.dram_ports)
    # edge-preserving placement: grayskull's top-row ports stay north
    mesh = spec.topology_spec
    assert all("north" in mesh.device_edges(p) for p in spec.dram_ports)


def test_hardware_search_preserves_multi_edge_dram_layout():
    """wafer_scale places DRAM ports on both vertical edges; a re-shaped
    variant must keep the two-edge layout (not collapse to the west
    column)."""
    from repro.core import wafer_scale
    base = wafer_scale()                             # 5 west + 5 east ports
    space = HardwareSearchSpace(mesh_shapes=((4, 4),))
    (spec,) = space.enumerate_specs(base)
    mesh = spec.topology_spec.flatten()
    assert (mesh.rows, mesh.cols) == (16, 16)
    assert len(spec.dram_ports) == len(base.dram_ports) == 10
    west = [p for p in spec.dram_ports if "west" in mesh.device_edges(p)]
    east = [p for p in spec.dram_ports if "east" in mesh.device_edges(p)]
    assert len(west) == 5 and len(east) == 5
    # and the variant still simulates + serializes
    assert spec.to_dict()["dram_ports"] == list(spec.dram_ports)


def test_experiment_sweeps_hardware_cross_parallelism():
    exp = _tiny_experiment(
        search=SearchSpace(max_plans=3, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12)))
    rep = exp.sweep()
    assert rep.num_hardware == 2
    assert len({r.hardware for r in rep.runs}) == 2
    thpts = [r.throughput for r in rep.runs]
    assert thpts == sorted(thpts, reverse=True)      # merged ranking
    # faster tiles win: best point comes from the higher-flops variant
    assert "197T" in rep.best.hardware
    back = SweepReport.from_json(rep.to_json())      # num_hardware round-trips
    assert back.num_hardware == 2 and back == rep


def test_resolve_hardware_d_model_calibration():
    lo = resolve_hardware("a100x8", d_model=4096)
    hi = resolve_hardware("a100x8", d_model=20480)
    assert hi.tile.compute_efficiency > lo.tile.compute_efficiency
    with pytest.raises(ValueError, match="a100x<N>"):
        resolve_hardware("wafer_scale", d_model=4096)


def test_hardware_search_rejects_undivisible_mesh_shape():
    from repro.api import MeshSpec, HardwareSpec
    from repro.core import DRAMSpec, TileSpec
    base = HardwareSpec(name="t",
                        topology=MeshSpec(8, 8, intra_bw=1e12, inter_bw=2.5e11,
                                          tile_shape=(4, 4)),
                        tile=TileSpec(flops=1e12, sram_bytes=1e6),
                        dram=DRAMSpec(bandwidth=1e11))
    with pytest.raises(ValueError, match="does not divide"):
        HardwareSearchSpace(mesh_shapes=((5, 5),)).enumerate_specs(base)


def test_mixed_edge_dram_ports_survive_corner_collisions():
    """West and north placements can both want the shared corner device;
    the port count must survive (slide to the nearest free device)."""
    from repro.api import MeshSpec
    from repro.core import DRAMSpec, TileSpec
    from repro.core.hardware import HardwareSpec as HS
    base = HS(name="mixed",
              topology=MeshSpec(4, 4, intra_bw=1e12),
              tile=TileSpec(flops=1e12, sram_bytes=1e6),
              dram=DRAMSpec(bandwidth=1e11, channels=5),
              dram_ports=(0, 4, 8, 1, 2))    # corner 0 + west col + north row
    (spec,) = HardwareSearchSpace(mesh_shapes=((4, 4),)).enumerate_specs(base)
    assert len(spec.dram_ports) == 5          # nothing silently dropped
    assert len(set(spec.dram_ports)) == 5
    mesh = spec.topology_spec
    west = sum("west" in mesh.device_edges(p) for p in spec.dram_ports)
    north = sum("north" in mesh.device_edges(p) for p in spec.dram_ports)
    assert west >= 3 and north >= 2           # both edges still populated


def test_hardware_search_counts_oversubscribed_variants_as_failed():
    """A variant too small for explicit search degrees must not abort the
    whole hardware sweep."""
    exp = _tiny_experiment(          # base tpu_v5e_2x2 has 4 devices
        search=SearchSpace(degrees=[(2, 2, 1)], microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        hardware_search=HardwareSearchSpace(mesh_shapes=((2, 2), (1, 2))))
    rep = exp.sweep()
    assert rep.num_hardware == 2
    assert rep.num_failed == 1               # the 1x2 variant (2 devices)
    assert rep.runs and all("2x2" in r.hardware for r in rep.runs)


# ---------------------------------------------------------------------------
# merged hardware x plan sweep through one shared pool
# ---------------------------------------------------------------------------

def _hw_cross_experiment(**kw):
    defaults = dict(
        search=SearchSpace(max_plans=4, microbatch_sizes=(1, 2)),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12),
                                            dram_bandwidth=(400e9, 819e9)))
    defaults.update(kw)
    return _tiny_experiment(**defaults)


def test_merged_hardware_sweep_serial_matches_shared_pool():
    """Tentpole acceptance: the flattened (hardware x plan) job stream
    through one shared process pool reproduces the serial ranking."""
    exp = _hw_cross_experiment()
    serial = exp.sweep(workers=0)
    pooled = exp.sweep(workers=2)
    assert serial.num_hardware == 4
    assert serial.runs, "merged sweep produced no feasible points"
    assert pooled.executor.startswith("process")
    assert [(r.hardware, r.plan) for r in serial.runs] == \
           [(r.hardware, r.plan) for r in pooled.runs]
    assert [r.throughput for r in serial.runs] == \
           [r.throughput for r in pooled.runs]
    assert serial.num_candidates == pooled.num_candidates
    assert serial.num_failed == pooled.num_failed


def test_merged_hardware_sweep_records_variant_specs():
    exp = _hw_cross_experiment()
    rep = exp.sweep()
    assert set(rep.hardware_specs) == {r.hardware for r in rep.runs}
    # the winning variant is recoverable from the report alone
    from repro.core.hardware import HardwareSpec as HS
    spec = HS.from_dict(rep.best_hardware_dict())
    assert spec.name == rep.best.hardware
    back = SweepReport.from_json(rep.to_json())
    assert back.hardware_specs == rep.hardware_specs and back == rep


def test_return_timelines_round_trips_through_the_pool():
    """return_timelines=True ships each run's full SimResult back from the
    workers; scalar results and JSON stay identical to the default."""
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=4, microbatch_sizes=(1,), layouts=(Layout.S_SHAPE,)))
    plain = exp.sweep(workers=2)
    timed = exp.sweep(workers=2, return_timelines=True)
    assert timed.executor.startswith("process")
    assert all(r.sim is not None and r.sim.timeline for r in timed.runs)
    assert all(r.sim is None for r in plain.runs)
    assert [r.plan for r in timed.runs] == [r.plan for r in plain.runs]
    assert [r.throughput for r in timed.runs] == \
           [r.throughput for r in plain.runs]
    # sim totals agree with the scalar digest shipped alongside
    assert all(r.sim.total_time == r.total_time for r in timed.runs)
    # RunReport stays scalar on the wire: sim is excluded from JSON and eq
    assert "sim" not in timed.runs[0].to_dict()
    assert timed.to_json() == plain.to_json()
    assert SweepReport.from_json(timed.to_json()) == plain


def test_merged_sweep_with_timelines_keeps_parity():
    exp = _hw_cross_experiment(
        search=SearchSpace(max_plans=3, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12)))
    serial = exp.sweep(workers=0, return_timelines=True)
    pooled = exp.sweep(workers=2, return_timelines=True)
    assert all(r.sim is not None for r in serial.runs + pooled.runs)
    assert [(r.hardware, r.plan) for r in serial.runs] == \
           [(r.hardware, r.plan) for r in pooled.runs]


# ---------------------------------------------------------------------------
# co-design planner (§VI loop)
# ---------------------------------------------------------------------------

def test_plan_codesign_picks_known_best_variant():
    """Rigged search space: one variant has ~2x the tile compute, so the
    co-design recommendation must name it."""
    from repro.api import PlannerCfg, plan_codesign
    from repro.configs import get_config
    cfg = PlannerCfg(
        global_batch=8, seq_len=128, max_plans=3, microbatch_sizes=(1,),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12)))
    res = plan_codesign(get_config("yi-6b"), tpu_v5e_pod(2, 2), cfg)
    assert "197T" in res.hardware.name
    assert res.hardware.tile.flops == 197e12
    assert res.run is res.report.best
    assert res.plan == res.report.best.plan
    # the recommendation is serializable end to end
    doc = res.to_dict()
    assert doc["hardware"]["tile"]["flops"] == 197e12
    assert doc["plan"]["pp"] == res.plan.pp
    from repro.core.hardware import HardwareSpec as HS
    assert HS.from_json(res.hardware.to_json()).to_dict() == \
        res.hardware.to_dict()


def test_plan_codesign_requires_hardware_search():
    from repro.api import PlannerCfg, plan_codesign
    from repro.configs import get_config
    with pytest.raises(ValueError, match="hardware_search"):
        plan_codesign(get_config("yi-6b"), tpu_v5e_pod(2, 2), PlannerCfg())


def test_plan_parallelism_accepts_hardware_search():
    from repro.api import PlannerCfg, plan_parallelism
    from repro.configs import get_config
    cfg = PlannerCfg(
        global_batch=8, seq_len=128, max_plans=3, microbatch_sizes=(1,),
        hardware_search=HardwareSearchSpace(tile_flops=(100e12, 197e12)))
    runs = plan_parallelism(get_config("yi-6b"), tpu_v5e_pod(2, 2), cfg)
    assert len({r.hardware for r in runs}) == 2      # joint ranking
    thpts = [r.throughput for r in runs]
    assert thpts == sorted(thpts, reverse=True)


# ---------------------------------------------------------------------------
# tpu_v5e torus preset
# ---------------------------------------------------------------------------

def test_tpu_v5e_torus_preset_resolves_and_round_trips():
    from repro.core import Torus2D
    hw = resolve_hardware("tpu_v5e_torus")
    assert isinstance(hw.topology, Torus2D)
    assert hw.name == "tpu_v5e_torus_16x16"
    small = resolve_hardware("tpu_v5e_torus_2x4")
    assert isinstance(small.topology, Torus2D) and small.num_devices == 8
    from repro.core.hardware import HardwareSpec as HS
    back = HS.from_json(small.to_json())
    assert back.to_dict() == small.to_dict()
    assert isinstance(back.topology, Torus2D)
    # mesh spelling unchanged
    from repro.core import Mesh2D
    mesh = resolve_hardware("tpu_v5e_2x4")
    assert type(mesh.topology) is Mesh2D


def test_tpu_v5e_torus_routes_no_longer_than_mesh():
    mesh = resolve_hardware("tpu_v5e_4x4").topology
    torus = resolve_hardware("tpu_v5e_torus_4x4").topology
    for src in range(16):
        for dst in range(16):
            assert torus.hops(src, dst) <= mesh.hops(src, dst)
