"""Unified Experiment API: enum coercion, validation, report round-trip,
sweep-engine parity (serial vs process pool vs legacy sweep_plans)."""

import pytest

from repro.api import (
    BoundaryMode,
    Experiment,
    Layout,
    NoCMode,
    ParallelPlan,
    RunReport,
    Schedule,
    SearchSpace,
    SweepEngine,
    SweepReport,
    resolve_hardware,
)
from repro.core import simulate, sweep_plans, transformer_lm_graph, tpu_v5e_pod
from repro.core.enums import coerce


# ---------------------------------------------------------------------------
# enum coercion (legacy strings accepted with DeprecationWarning)
# ---------------------------------------------------------------------------

def test_coerce_accepts_enum_silently():
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert coerce(Schedule, Schedule.GPIPE, "schedule") is Schedule.GPIPE


@pytest.mark.parametrize("cls,raw,member", [
    (Schedule, "1f1b", Schedule.ONE_F_ONE_B),
    (Schedule, "gpipe", Schedule.GPIPE),
    (Layout, "s_shape", Layout.S_SHAPE),
    (Layout, "line", Layout.LINE),
    (NoCMode, "macro", NoCMode.MACRO),
    (BoundaryMode, "strategy", BoundaryMode.STRATEGY),
])
def test_coerce_legacy_string_warns(cls, raw, member):
    with pytest.warns(DeprecationWarning, match="deprecated"):
        assert coerce(cls, raw, "x") is member


def test_coerce_unknown_string_raises():
    with pytest.raises(ValueError, match="unknown schedule"):
        coerce(Schedule, "one_f_one_b", "schedule")


def test_parallel_plan_coerces_legacy_strings():
    with pytest.warns(DeprecationWarning):
        plan = ParallelPlan(schedule="gpipe", layout="line")
    assert plan.schedule is Schedule.GPIPE
    assert plan.layout is Layout.LINE
    # str-subclass enums keep legacy comparisons working
    assert plan.schedule == "gpipe"


def test_simulate_coerces_legacy_noc_mode():
    g = transformer_lm_graph("t", 2, 128, 4, seq_len=64, batch=1, vocab=256)
    hw = tpu_v5e_pod(2, 2)
    with pytest.warns(DeprecationWarning):
        res = simulate(g, hw, ParallelPlan(global_batch=2), noc_mode="macro")
    assert res.throughput > 0


def test_unknown_schedule_string_raises_in_plan():
    with pytest.raises(ValueError, match="unknown schedule"):
        ParallelPlan(schedule="2f2b")


# ---------------------------------------------------------------------------
# Experiment validation
# ---------------------------------------------------------------------------

def test_experiment_requires_plan_or_search():
    with pytest.raises(ValueError, match="plan.*or.*search"):
        Experiment(arch="yi-6b")
    with pytest.raises(ValueError, match="not both"):
        Experiment(arch="yi-6b", plan=ParallelPlan(),
                   search=SearchSpace())


def test_experiment_rejects_bad_factorization():
    hw = tpu_v5e_pod(2, 2)      # 4 devices
    with pytest.raises(ValueError, match="needs 8 devices"):
        Experiment(arch="yi-6b", hardware=hw,
                   plan=ParallelPlan(pp=2, dp=2, tp=2, global_batch=4))


def test_experiment_rejects_bad_batch_split():
    hw = tpu_v5e_pod(2, 2)
    with pytest.raises(ValueError, match="not divisible"):
        Experiment(arch="yi-6b", hardware=hw,
                   plan=ParallelPlan(pp=1, dp=2, tp=2, microbatch=2,
                                     global_batch=6))


def test_experiment_rejects_unknown_names():
    with pytest.raises(KeyError, match="unknown arch"):
        Experiment(arch="not-a-model", plan=ParallelPlan())
    with pytest.raises(ValueError, match="unknown hardware preset"):
        Experiment(arch="yi-6b", hardware="cerebras-42", plan=ParallelPlan())


def test_search_space_rejects_oversubscribed_degrees():
    hw = tpu_v5e_pod(2, 2)
    space = SearchSpace(degrees=[(2, 2, 2)])
    with pytest.raises(ValueError, match="needs 8"):
        space.enumerate_plans(hw, global_batch=8)


def test_resolve_hardware_presets():
    assert resolve_hardware("grayskull").name == "grayskull"
    assert resolve_hardware("a100x16").num_devices == 16
    assert resolve_hardware("tpu_v5e_2x2").num_devices == 4


# ---------------------------------------------------------------------------
# report JSON round-trip
# ---------------------------------------------------------------------------

def _tiny_experiment(**kw):
    defaults = dict(
        arch="yi-6b",
        hardware=tpu_v5e_pod(2, 2),
        seq_len=128,
        global_batch=8,
    )
    defaults.update(kw)
    return Experiment(**defaults)


def test_run_report_json_round_trip():
    exp = _tiny_experiment(plan=ParallelPlan(pp=2, dp=2, tp=1, global_batch=8))
    rep = exp.run()
    back = RunReport.from_json(rep.to_json())
    assert back == rep
    assert isinstance(back.plan, ParallelPlan)
    assert back.plan.schedule is Schedule.ONE_F_ONE_B
    assert back.throughput == rep.throughput


def test_sweep_report_json_round_trip():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=4, microbatch_sizes=(1,), layouts=(Layout.S_SHAPE,)))
    rep = exp.sweep()
    assert rep.runs
    back = SweepReport.from_json(rep.to_json())
    assert back == rep


# ---------------------------------------------------------------------------
# sweep engine: parity + pruning
# ---------------------------------------------------------------------------

def test_sweep_engine_serial_matches_process_pool():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=6, microbatch_sizes=(1, 2)))
    serial = exp.sweep(workers=0)
    pooled = exp.sweep(workers=2)
    assert serial.runs, "sweep produced no feasible plans"
    assert [r.plan for r in serial.runs] == [r.plan for r in pooled.runs]
    assert [r.throughput for r in serial.runs] == \
           [r.throughput for r in pooled.runs]
    assert pooled.executor.startswith("process")


def test_sweep_engine_matches_legacy_sweep_plans():
    """Acceptance: a >= 24-plan search space ranked by the process-pool
    SweepEngine reproduces the legacy serial sweep_plans ranking."""
    exp = _tiny_experiment(
        global_batch=16,
        search=SearchSpace(max_plans=48, microbatch_sizes=(1, 2, 4),
                           tp_contiguous=(True, False)))
    plans = exp.search.enumerate_plans(exp.hardware_spec, exp.global_batch,
                                       training=True, arch=exp.arch_config)
    assert len(plans) >= 24
    legacy = sweep_plans(exp.build_graph, exp.hardware_spec, plans,
                         noc_mode=NoCMode.MACRO)
    engine = SweepEngine(workers=2).sweep(exp, plans)
    assert engine.executor.startswith("process")
    assert [r.plan for r in legacy] == [r.plan for r in engine.runs]
    assert [r.throughput for r in legacy] == \
           pytest.approx([r.throughput for r in engine.runs])


def test_memory_cap_prunes_before_simulation():
    exp = _tiny_experiment(search=SearchSpace(
        max_plans=6, microbatch_sizes=(1, 2)))
    base = exp.sweep()
    mems = sorted(r.peak_memory_bytes for r in base.runs)
    cap = mems[len(mems) // 2]          # prune the top half
    capped = exp.with_(memory_cap=cap).sweep()
    assert capped.num_pruned_memory > 0
    assert all(r.peak_memory_bytes <= cap for r in capped.runs)
    # parity with the legacy post-hoc filter: same surviving ranking
    expect = [r.plan for r in base.runs if r.peak_memory_bytes <= cap]
    assert [r.plan for r in capped.runs] == expect


def test_graph_builder_experiments_sweep_serially():
    exp = Experiment(
        graph_builder=lambda p: transformer_lm_graph(
            "t", 2, 128, 4, seq_len=64, batch=p.microbatch * p.dp, vocab=256),
        hardware=tpu_v5e_pod(2, 2),
        search=SearchSpace(max_plans=3, microbatch_sizes=(1,),
                           layouts=(Layout.S_SHAPE,)),
        global_batch=4,
    )
    with pytest.warns(RuntimeWarning, match="not picklable"):
        rep = exp.sweep(workers=2)     # lambda builder -> serial fallback
    assert rep.runs and rep.executor == "serial"
