"""Traffic-driven serving simulator: workload generators, continuous
batching / KV pressure, SLO metrics, the serving-scored sweep path, and
SLO-aware co-design (the serving-subsystem PR)."""

import json
import math
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.serving.batcher import ContinuousBatcher, KVCacheModel
from repro.serving.system import ServingReport, ServingSpec, StepCostModel, simulate_serving
from repro.serving.workload import (
    Request,
    WorkloadSpec,
    workload_from_json,
    workload_to_json,
)

GOLDEN = Path(__file__).parent / "data" / "serving_golden.json"

TINY_WORKLOAD = WorkloadSpec(rate=2.0, num_requests=10, seed=3,
                             prompt_mean=64, decode_mean=8,
                             prompt_cv=0.5, decode_cv=0.5)
TINY_SPEC = ServingSpec(workload=TINY_WORKLOAD, max_batch=4, ctx_bucket=128)


def _attn_arch(**kw) -> ArchConfig:
    base = dict(name="toy-attn", family="test", num_layers=4, d_model=256,
                n_heads=8, n_kv=4, d_ff=512, vocab=1000, head_dim=32)
    base.update(kw)
    return ArchConfig(**base)


# ---------------------------------------------------------------------------
# workload generators
# ---------------------------------------------------------------------------

def test_poisson_workload_is_seed_deterministic():
    a = TINY_WORKLOAD.generate()
    b = TINY_WORKLOAD.generate()
    assert a == b
    c = WorkloadSpec(rate=2.0, num_requests=10, seed=4,
                     prompt_mean=64, decode_mean=8,
                     prompt_cv=0.5, decode_cv=0.5).generate()
    assert a != c
    assert all(r.arrival <= s.arrival for r, s in zip(a, a[1:]))
    assert all(r.decode_len >= 1 for r in a)


def test_bursty_workload_generates_and_differs_from_poisson():
    poisson = TINY_WORKLOAD.generate()
    bursty = WorkloadSpec(kind="bursty", rate=2.0, num_requests=10, seed=3,
                          prompt_mean=64, decode_mean=8,
                          prompt_cv=0.5, decode_cv=0.5).generate()
    assert len(bursty) == 10
    assert [r.arrival for r in bursty] != [r.arrival for r in poisson]
    assert all(r.arrival <= s.arrival for r, s in zip(bursty, bursty[1:]))


def test_workload_trace_replay_round_trip():
    reqs = TINY_WORKLOAD.generate()
    replay = workload_from_json(workload_to_json(reqs))
    assert replay.kind == "replay"
    assert replay.generate() == reqs
    # replay's offered rate spans the recorded arrivals
    span = reqs[-1].arrival - reqs[0].arrival
    assert replay.offered_rate == pytest.approx((len(reqs) - 1) / span)


def test_workload_spec_dict_round_trip():
    spec = WorkloadSpec(kind="bursty", rate=3.0, num_requests=7, seed=9,
                        burst_factor=2.5)
    assert WorkloadSpec.from_dict(spec.to_dict()) == spec


def test_cv_zero_means_fixed_lengths():
    reqs = WorkloadSpec(rate=1.0, num_requests=5, seed=0,
                        prompt_mean=100, decode_mean=20).generate()
    assert {r.prompt_len for r in reqs} == {100}
    assert {r.decode_len for r in reqs} == {20}


# ---------------------------------------------------------------------------
# KV-cache model + batcher policy
# ---------------------------------------------------------------------------

def test_kv_cache_model_attention_bytes():
    arch = _attn_arch()
    kv = KVCacheModel.from_arch(arch, precision_bytes=2)
    # 2 (K+V) x n_kv x head_dim x precision x layers per token
    assert kv.per_token_bytes == 2 * 4 * 32 * 2 * 4
    assert kv.fixed_bytes == 0
    assert kv.request_bytes(10) == 10 * kv.per_token_bytes


def test_kv_cache_model_window_caps_tokens():
    kv = KVCacheModel.from_arch(_attn_arch(window=16), precision_bytes=2)
    assert kv.request_bytes(8) == 8 * kv.per_token_bytes
    assert kv.request_bytes(100) == 16 * kv.per_token_bytes


def test_kv_cache_model_ssm_is_fixed_size():
    arch = ArchConfig(name="toy-ssm", family="test", num_layers=2,
                      d_model=256, n_heads=8, n_kv=8, d_ff=512, vocab=1000,
                      block="ssm", ssm_state=64, d_inner=512, conv_width=4)
    kv = KVCacheModel.from_arch(arch, precision_bytes=2)
    assert kv.per_token_bytes == 0
    assert kv.fixed_bytes == 2 * 2 * (512 * 64 + 512 * 4)
    assert kv.request_bytes(1) == kv.request_bytes(10_000)


def _batcher(budget, max_batch=4, policy="continuous"):
    kv = KVCacheModel(per_token_bytes=1.0, fixed_bytes=0.0)
    return ContinuousBatcher(kv, kv_budget_bytes=budget,
                             max_batch=max_batch, policy=policy)


def test_batcher_rejects_request_that_can_never_fit():
    b = _batcher(budget=10.0)
    assert b.add(Request(rid=0, arrival=0.0, prompt_len=8, decode_len=8),
                 now=0.0) is None
    assert len(b.rejected) == 1 and not b.waiting
    assert b.add(Request(rid=1, arrival=0.0, prompt_len=4, decode_len=2),
                 now=0.0) is not None


def test_batcher_preempts_lifo_and_resumes_at_front():
    b = _batcher(budget=20.0, max_batch=3)
    for rid in range(3):
        b.add(Request(rid=rid, arrival=0.0, prompt_len=4, decode_len=10),
              now=0.0)
    admitted = b.admit(now=0.0)
    assert [a.rid for a in admitted] == [0, 1, 2]
    b.finish_prefill(admitted, now=1.0)       # contexts 5 each -> 15 bytes
    b.finish_decode(now=2.0)                  # 18 bytes, fits
    retired, evicted = b.finish_decode(now=3.0)   # 21 bytes > 20: evict
    assert not retired
    assert [a.rid for a in evicted] == [2]    # LIFO: newest admission
    assert b.waiting[0].rid == 2              # resumes at the queue front
    victim = b.waiting[0]
    assert victim.episode == 1 and victim.context == 0
    assert victim.resume_context == 4 + victim.generated  # recompute-on-resume
    assert b.preemptions == 1


def test_batcher_never_evicts_last_running_request():
    b = _batcher(budget=12.0, max_batch=2)
    b.add(Request(rid=0, arrival=0.0, prompt_len=4, decode_len=8), now=0.0)
    admitted = b.admit(now=0.0)
    b.finish_prefill(admitted, now=1.0)
    for step in range(6):                     # grows past the budget alone?
        _, evicted = b.finish_decode(now=2.0 + step)
        assert not evicted                    # deadlock guard: add() vetted it
    assert len(b.running) == 1


def test_static_policy_blocks_admission_until_batch_drains():
    b = _batcher(budget=1e9, max_batch=2, policy="static")
    for rid in range(3):
        b.add(Request(rid=rid, arrival=0.0, prompt_len=2, decode_len=2),
              now=0.0)
    first = b.admit(now=0.0)
    assert [a.rid for a in first] == [0, 1]
    assert b.admit(now=1.0) == []             # batch still running
    b.finish_prefill(first, now=1.0)
    b.finish_decode(now=2.0)                  # both retire (decode_len=2)
    assert not b.running
    assert [a.rid for a in b.admit(now=3.0)] == [2]


# ---------------------------------------------------------------------------
# the serving simulator
# ---------------------------------------------------------------------------

def test_serving_report_bit_reproducible_and_round_trips():
    a = simulate_serving("hymba-1.5b", "grayskull", None, TINY_SPEC)
    b = simulate_serving("hymba-1.5b", "grayskull", None, TINY_SPEC)
    assert a.to_json() == b.to_json()
    back = ServingReport.from_json(a.to_json())
    assert back.to_json() == a.to_json()
    assert a.completed == TINY_SPEC.workload.num_requests
    assert a.goodput_rps <= a.throughput_rps
    assert 0.0 <= a.slo_attainment <= 1.0
    # the SLO curve is monotone in the scale
    atts = [pt["attainment"] for pt in a.slo_curve]
    assert atts == sorted(atts)


def test_serving_golden_report_fixture():
    """The tiny Poisson run is locked down bit-for-bit. Regenerate with:
    PYTHONPATH=src python tests/test_serving.py regen"""
    got = simulate_serving("hymba-1.5b", "grayskull", None, TINY_SPEC).to_dict()
    want = json.loads(GOLDEN.read_text())
    assert got == want


def _grayskull_kv() -> KVCacheModel:
    """The exact KV model the simulator builds: hardware precision, not a
    guessed one (grayskull serves at 1 byte/elem)."""
    from repro.api.experiment import resolve_hardware
    hw = resolve_hardware("grayskull")
    return KVCacheModel.from_arch(get_config("hymba-1.5b"),
                                  hw.precision_bytes)


def test_kv_pressure_causes_preemption_and_recovery():
    workload = WorkloadSpec(rate=50.0, num_requests=6, seed=0,
                            prompt_mean=32, decode_mean=16)
    kv = _grayskull_kv()
    # three requests fit at prompt size but not at full context: decode
    # growth pushes occupancy over the budget and forces an eviction
    budget = kv.request_bytes(32 + 16) * 2.8
    spec = ServingSpec(workload=workload, max_batch=4, ctx_bucket=64,
                       kv_budget_bytes=budget)
    rep = simulate_serving("hymba-1.5b", "grayskull", None, spec)
    assert rep.preemptions > 0
    assert rep.completed == 6                 # everyone finishes eventually
    assert rep.kv_peak_bytes <= budget
    assert rep.kv_budget_bytes == budget


def test_serving_rejects_request_larger_than_budget():
    workload = WorkloadSpec(rate=10.0, num_requests=4, seed=0,
                            prompt_mean=512, decode_mean=8)
    kv = _grayskull_kv()
    spec = ServingSpec(workload=workload, max_batch=4, ctx_bucket=64,
                       kv_budget_bytes=kv.request_bytes(100))
    rep = simulate_serving("hymba-1.5b", "grayskull", None, spec)
    assert rep.rejected == 4 and rep.completed == 0
    assert rep.slo_attainment == 0.0          # rejections count as misses


def test_continuous_beats_static_goodput_on_rigged_workload():
    """The benchmark gate in miniature: high-variance decode lengths hold
    static batches hostage while continuous batching recycles slots."""
    def run(policy):
        workload = WorkloadSpec(rate=1.0, num_requests=24, seed=1,
                                prompt_mean=64, prompt_cv=0.5,
                                decode_mean=16, decode_cv=2.0)
        spec = ServingSpec(workload=workload, max_batch=4, ctx_bucket=128,
                           policy=policy, slo_ttft_ms=1500.0,
                           slo_tpot_ms=250.0)
        return simulate_serving("hymba-1.5b", "grayskull", None, spec)
    assert run("continuous").goodput_rps >= 1.5 * run("static").goodput_rps


def test_step_cost_model_memoizes_by_bucket():
    arch = get_config("hymba-1.5b")
    from repro.api.experiment import resolve_hardware
    from repro.core.parallelism import ParallelPlan
    from repro.core.enums import Schedule
    plan = ParallelPlan(pp=1, dp=1, tp=1, microbatch=1, global_batch=1,
                        schedule=Schedule.GPIPE, training=False)
    cost = StepCostModel(arch, resolve_hardware("grayskull"), plan,
                         ctx_bucket=128)
    a = cost.decode_cost(3, 100)
    b = cost.decode_cost(4, 120)              # same batch/ctx buckets
    assert a == b and cost.sims == 1
    cost.decode_cost(4, 200)                  # new ctx bucket
    assert cost.sims == 2
    assert cost.prefill_cost(4, 120) != a     # prefill is a separate key
    assert cost.sims == 3


def test_derived_kv_budget_unbounded_on_inf_dram():
    arch = get_config("hymba-1.5b")
    from repro.api.experiment import resolve_hardware
    from repro.core.parallelism import ParallelPlan
    from repro.core.enums import Schedule
    plan = ParallelPlan(pp=1, dp=1, tp=1, microbatch=1, global_batch=1,
                        schedule=Schedule.GPIPE, training=False)
    cost = StepCostModel(arch, resolve_hardware("grayskull"), plan,
                         ctx_bucket=128)
    assert math.isinf(cost.derive_kv_budget())


# ---------------------------------------------------------------------------
# per-request trace lanes
# ---------------------------------------------------------------------------

def _traced_report():
    workload = WorkloadSpec(rate=50.0, num_requests=6, seed=0,
                            prompt_mean=32, decode_mean=16)
    kv = _grayskull_kv()
    spec = ServingSpec(workload=workload, max_batch=4, ctx_bucket=64,
                       kv_budget_bytes=kv.request_bytes(48) * 2.8)
    return simulate_serving("hymba-1.5b", "grayskull", None, spec,
                            collect_trace=True)


def test_serving_trace_has_request_lanes_and_round_trips():
    from repro.core.trace import (
        KIND_DECODE, KIND_PREFILL, KIND_QUEUE, Trace,
    )
    rep = _traced_report()
    trace = rep.trace
    kinds = set(trace.kind)
    assert {KIND_PREFILL, KIND_DECODE} <= kinds
    assert KIND_QUEUE in kinds                # eviction re-queues requests
    # resource column carries the request id; stage is -1 for request lanes
    assert set(trace.stage) == {-1}
    assert set(trace.resource) <= set(range(6))
    # an evicted request decodes over more than one episode
    assert max(trace.micro) >= 1
    back = Trace.from_bytes(trace.to_bytes())
    assert back.to_bytes() == trace.to_bytes()


def test_serving_trace_npz_round_trip(tmp_path):
    np = pytest.importorskip("numpy")  # noqa: F841 — npz needs numpy
    from repro.core.trace import Trace
    rep = _traced_report()
    path = tmp_path / "serving.npz"
    rep.trace.to_npz(path)
    back = Trace.from_npz(path)
    assert back.to_bytes() == rep.trace.to_bytes()


def test_serving_chrome_trace_request_process():
    from repro.core.trace import chrome_trace
    rep = _traced_report()
    doc = chrome_trace(rep.trace, label="serving")
    events = doc["traceEvents"]
    req = [e for e in events if e.get("pid") == 3 and e.get("ph") == "X"]
    assert req, "per-request lanes missing from the Chrome export"
    names = {e["name"] for e in req}
    assert any(n.startswith("PREFILL ep") for n in names)
    assert any(n.startswith("DECODE ep") for n in names)
    meta = [e for e in events
            if e.get("ph") == "M" and e.get("name") == "process_name"]
    assert any("requests" in m["args"]["name"] for m in meta)
    # thread ids are request ids
    assert all(isinstance(e["tid"], int) for e in req)


# ---------------------------------------------------------------------------
# the serving-scored sweep path (Experiment.serving)
# ---------------------------------------------------------------------------

def _serving_experiment(workers_unused=None):
    from repro.api import Experiment, SearchSpace
    from repro.core.enums import Layout
    return Experiment(
        arch="hymba-1.5b", hardware="grayskull",
        search=SearchSpace(degrees=[(1, 1, 4), (1, 2, 2), (1, 4, 1)],
                           microbatch_sizes=(1,), layouts=(Layout.S_SHAPE,),
                           max_plans=3),
        seq_len=128, global_batch=4, training=False, decode=True,
        serving=TINY_SPEC)


def test_serving_sweep_serial_equals_pool_bit_for_bit():
    exp = _serving_experiment()
    serial, pooled = exp.sweep(workers=0).to_dict(), exp.sweep(workers=2).to_dict()
    assert serial.pop("executor") == "serial"
    assert pooled.pop("executor") == "process[2]"
    assert serial == pooled
    runs = serial["runs"]
    assert all("serving" in r["extra"] for r in runs)
    goodputs = [r["throughput"] for r in runs]
    assert goodputs == sorted(goodputs, reverse=True)
    # throughput IS the embedded report's goodput
    for r in runs:
        assert r["throughput"] == r["extra"]["serving"]["goodput_rps"]


def test_serving_experiment_requires_inference_mode():
    from repro.api import Experiment, SearchSpace
    with pytest.raises(ValueError, match="training=False"):
        Experiment(arch="hymba-1.5b", hardware="grayskull",
                   search=SearchSpace(max_plans=1), serving=TINY_SPEC)


# ---------------------------------------------------------------------------
# planners: persistent engines, infeasibility diagnostics, SLO co-design
# ---------------------------------------------------------------------------

def test_plan_serving_reuses_persistent_engine_pool():
    from repro.api.sweep import SweepEngine
    from repro.serving.planner import plan_serving
    with SweepEngine(workers=2) as eng:
        mesh_a, report_a = plan_serving("yi-6b", "tpu_v5e_2x2", batch=4,
                                        context_len=128, engine=eng)
        mesh_b, report_b = plan_serving("yi-6b", "tpu_v5e_2x2", batch=4,
                                        context_len=128, engine=eng)
        # same spec both calls: the worker pool was initialized exactly once
        assert eng.pool_inits == 1
    assert mesh_a == mesh_b
    assert report_a.executor == "process[2]"
    assert {"data", "model"} <= set(mesh_a)
    assert mesh_a["data"] * mesh_a["model"] == 4


def test_plan_serving_explains_infeasibility():
    from repro.serving.planner import plan_serving
    with pytest.raises(RuntimeError) as err:
        plan_serving("yi-6b", "tpu_v5e_2x2", batch=4, context_len=128,
                     memory_cap=1e6)
    msg = str(err.value)
    assert "no feasible serving split" in msg
    assert "memory-pruned" in msg
    # every split is named with its per-tile deficit
    assert "(dp=1, tp=4)" in msg and "(dp=4, tp=1)" in msg
    assert "over the" in msg and "cap by" in msg


def test_sweep_report_carries_pruning_records():
    from repro.api import Experiment, SearchSpace
    from repro.api.report import SweepReport
    exp = Experiment(arch="yi-6b", hardware="tpu_v5e_2x2",
                     search=SearchSpace(max_plans=3, microbatch_sizes=(1,)),
                     seq_len=128, global_batch=8, memory_cap=1e6)
    report = exp.sweep(workers=0)
    assert report.num_pruned_memory == len(report.pruned_records) > 0
    rec = report.pruned_records[0]
    assert rec["deficit_bytes"] == rec["peak_bytes"] - rec["cap_bytes"] > 0
    assert {"pp", "dp", "tp", "microbatch"} <= set(rec["plan"])
    # records survive the report JSON round-trip
    back = SweepReport.from_json(report.to_json())
    assert back.pruned_records == report.pruned_records


def test_plan_codesign_slo_objective_flips_the_winner():
    """Rigged co-design space: the step-time objective picks a pipelined
    plan on the 1x4 mesh (best training throughput); under a tight TPOT
    SLO the serving objective needs tensor-parallel decode and picks the
    2x2 mesh instead."""
    from repro.api import HardwareSearchSpace
    from repro.core.hardware import tpu_v5e_pod
    from repro.core.planner import PlannerCfg, plan_codesign
    arch = get_config("yi-6b")
    hw = tpu_v5e_pod(2, 2)
    slo = ServingSpec(
        workload=WorkloadSpec(rate=8.0, num_requests=12, seed=0,
                              prompt_mean=128, decode_mean=16),
        max_batch=4, ctx_bucket=128, slo_ttft_ms=500.0, slo_tpot_ms=8.0)
    cfg = PlannerCfg(global_batch=32, seq_len=256, microbatch_sizes=(1,),
                     max_plans=8, slo=slo,
                     hardware_search=HardwareSearchSpace(
                         mesh_shapes=((1, 4), (2, 2))))
    step = plan_codesign(arch, hw, cfg)
    served = plan_codesign(arch, hw, cfg, objective="slo")
    step_winner = (step.hardware.name, step.plan.pp, step.plan.dp, step.plan.tp)
    slo_winner = (served.hardware.name, served.plan.pp, served.plan.dp,
                  served.plan.tp)
    assert step_winner != slo_winner
    assert served.objective == "slo" and "req/s" in served.summary()
    # the serving winner actually meets the SLO; the step-time winner's
    # split does not (that is what makes the rig a rig)
    best = served.run.extra["serving"]
    assert best["slo"]["attainment"] > 0.5
    ranked = {(r.hardware, r.plan.pp, r.plan.dp, r.plan.tp): r
              for r in served.report.runs}
    step_as_served = ranked.get(step_winner)
    if step_as_served is not None:
        assert step_as_served.throughput < served.run.throughput


def test_plan_codesign_rejects_unknown_objective():
    from repro.core.planner import PlannerCfg, plan_parallelism
    with pytest.raises(ValueError, match="unknown objective"):
        plan_parallelism(get_config("yi-6b"), None, PlannerCfg(),
                         objective="latency")


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "regen":
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        doc = simulate_serving("hymba-1.5b", "grayskull", None,
                               TINY_SPEC).to_dict()
        GOLDEN.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"[golden fixture written to {GOLDEN}]")
