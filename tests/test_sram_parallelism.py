"""Alg. 1 SRAM allocation + Adaptive Parallelism Interface (Table III)."""

import math

import pytest

from repro.core import (
    Conv2,
    Linear,
    ParallelPlan,
    TransformerLayer,
    grayskull,
    make_groups,
    map_graph,
    s_shape_layout,
    line_layout,
    split_op,
    transformer_lm_graph,
    wafer_scale,
)
from repro.core.graph import ComputationGraph, MoELayer, Pool
from repro.core.parallelism import FD, BD, GU
from repro.core.sram import allocate_stage, stage_memory
from proptools import given


# ---------------------------------------------------------------- Table III

def test_linear_comm_sizes():
    op = Linear(name="l", B=4, M=64, N=32, K=128)
    s = split_op(op, {"b": 2, "m": 2, "n": 2, "k": 2})
    fd = [c for c in s.comms if c.phase == FD]
    assert len(fd) == 1 and fd[0].kind == "all_reduce" and fd[0].axis == "k"
    assert fd[0].elems == op.B * op.M * op.N / 8          # BMN/(bmn)
    bd = [c for c in s.comms if c.phase == BD]
    assert bd[0].elems == op.B * op.N * op.K / 8          # BNK/(bnk), m-group
    gu = [c for c in s.comms if c.phase == GU]
    assert {c.axis for c in gu} == {"b", "n"}
    assert all(c.elems == op.M * op.K / 4 for c in gu)    # MK/(mk)
    assert s.fwd_flops_tile == op.fwd_flops() / 16


def test_transformer_comm_is_megatron():
    op = TransformerLayer(name="t", B=8, S=128, H=256, n_heads=8, n_kv=8,
                          d_ff=1024, gated_mlp=False)
    s = split_op(op, {"dp": 4, "tp": 2})
    fd = [c for c in s.comms if c.phase == FD][0]
    assert fd.elems == 2 * op.B * op.S * op.H / 4         # (2BSH/Nd, Nm)
    gu = [c for c in s.comms if c.phase == GU][0]
    assert gu.elems == op.param_count() / 2               # (params/Nm, Nd)


def test_transformer_flops_reduce_to_paper_formula():
    B, S, H = 2, 64, 128
    op = TransformerLayer(name="t", B=B, S=S, H=H, n_heads=8, n_kv=8,
                          d_ff=4 * H, gated_mlp=False, causal=False)
    assert op.fwd_flops() == pytest.approx(24 * B * S * H ** 2 + 4 * B * S ** 2 * H)


def test_moe_all_to_all():
    op = MoELayer(name="m", B=4, S=64, H=128, n_experts=8, top_k=2, d_ff_expert=64)
    s = split_op(op, {"dp": 2, "tp": 4})
    a2a = [c for c in s.comms if c.kind == "all_to_all" and c.phase == FD]
    assert len(a2a) == 2                                   # dispatch + combine
    assert a2a[0].elems == op.B * op.S * op.top_k * op.H / 2


# ------------------------------------------------------------------- groups

def test_make_groups_contiguous_vs_spread():
    devs = list(range(8))
    g1 = make_groups(devs, {"dp": 2, "tp": 4}, axis_order=["dp", "tp"])
    assert g1["tp"][0] == [0, 1, 2, 3]                     # comm1: contiguous
    g2 = make_groups(devs, {"dp": 2, "tp": 4}, axis_order=["tp", "dp"])
    assert g2["tp"][0] == [0, 2, 4, 6]                     # comm2: strided
    # groups partition the device set
    flat = sorted(d for g in g1["tp"] for d in g)
    assert flat == devs


def test_layouts():
    topo = wafer_scale().topology
    line = line_layout(topo, 4)
    s = s_shape_layout(topo, 4)
    assert len(line) == len(s) == 4
    assert sorted(sum(line, [])) == sorted(sum(s, []))     # same tiles overall
    assert line != s


# -------------------------------------------------------------------- Alg 1

def _stage_for(ops, plan, hw):
    g = ComputationGraph(ops=ops, name="g")
    return map_graph(g, hw, plan).stages[0]


def test_alg1_weight_resident_streams_acts():
    hw = wafer_scale()
    plan = ParallelPlan(dp=1, tp=1, training=True, global_batch=1, microbatch=1)
    tiny = Linear(name="l", B=1, M=64, N=128, K=64)        # 4k params: fits
    st = _stage_for([tiny], plan, hw)
    acc = allocate_stage(st, plan, hw, streaming_acts=False)[0]
    assert acc.strategy in ("sram_resident", "activation_stream")


def test_alg1_penalty_phi_choice():
    hw = grayskull()                                        # 1 MB SRAM
    plan = ParallelPlan(dp=1, tp=1, training=True, global_batch=1, microbatch=1)
    # weights >> acts (both over SRAM cap) -> weight_stationary (phi1 < phi2)
    ws_op = Linear(name="w", B=1, M=4096, N=512, K=4096)
    st = _stage_for([ws_op], plan, hw)
    acc = allocate_stage(st, plan, hw, streaming_acts=False)[0]
    assert acc.strategy == "weight_stationary"
    # acts >> weights (both over SRAM cap) -> input_stationary
    is_op = Linear(name="i", B=1, M=240, N=12800, K=4096)
    st = _stage_for([is_op], plan, hw)
    acc2 = allocate_stage(st, plan, hw, streaming_acts=False)[0]
    assert acc2.strategy == "input_stationary"


@given(n_cases=10)
def test_prop_alg1_chosen_strategy_minimizes_traffic(rng, case):
    """Penalty-branch invariant: the chosen phi is the smaller one."""
    hw = grayskull()
    plan = ParallelPlan(dp=1, tp=1, training=True, global_batch=1, microbatch=1)
    op = Linear(name="x", B=int(rng.integers(1, 8)),
                M=int(rng.integers(512, 8192)), N=int(rng.integers(512, 8192)),
                K=int(rng.integers(512, 4096)))
    st = _stage_for([op], plan, hw)
    acc = allocate_stage(st, plan, hw, streaming_acts=False)[0]
    cap = hw.tile.sram_bytes
    wt = op.param_count() * hw.precision_bytes
    act = op.in_elems() * hw.precision_bytes
    if acc.strategy == "weight_stationary":
        assert math.ceil(wt / cap) * act <= math.ceil(act / cap) * wt
    elif acc.strategy == "input_stationary":
        assert math.ceil(act / cap) * wt <= math.ceil(wt / cap) * act


def test_memory_gpipe_vs_1f1b():
    """§IV-B: first stage stores B (GPipe) vs S (1F1B) microbatch acts."""
    hw = wafer_scale()
    g = transformer_lm_graph("t", 8, 256, 8, 128, 4, vocab=1000)
    base = dict(pp=4, dp=2, tp=2, microbatch=2, global_batch=64)
    m_g = map_graph(g, hw, ParallelPlan(schedule="gpipe", **base))
    m_f = map_graph(g, hw, ParallelPlan(schedule="1f1b", **base))
    plan_g, plan_f = m_g.plan, m_f.plan
    s0_g = stage_memory(m_g.stages[0], plan_g, hw)
    s0_f = stage_memory(m_f.stages[0], plan_f, hw)
    assert s0_g.inflight_microbatches == plan_g.num_microbatches      # B
    assert s0_f.inflight_microbatches == min(4, plan_f.num_microbatches)  # S
    assert s0_g.activations >= s0_f.activations


def test_zero_shards_optimizer_state():
    hw = wafer_scale()
    g = transformer_lm_graph("t", 4, 256, 8, 128, 4, vocab=1000)
    base = dict(pp=2, dp=4, tp=2, microbatch=1, global_batch=16)
    m0 = map_graph(g, hw, ParallelPlan(zero=0, **base))
    m1 = map_graph(g, hw, ParallelPlan(zero=1, **base))
    s0 = stage_memory(m0.stages[0], m0.plan, hw)
    s1 = stage_memory(m1.stages[0], m1.plan, hw)
    assert s1.opt_state == pytest.approx(s0.opt_state / 4)


def test_stage_partition_covers_and_balances():
    g = transformer_lm_graph("t", 12, 256, 8, 128, 4, vocab=1000)
    for n in (2, 3, 6, 12, 14):
        stages = g.partition_stages(n)
        assert len(stages) == n
        assert all(len(s) > 0 for s in stages)
        assert sorted(sum(stages, [])) == list(range(len(g.ops)))
