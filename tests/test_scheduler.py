"""Pipeline scheduler: Eq. (1) bound, bubble behaviour, schedules,
recompute, inference throughput."""

import pytest

from repro.core import (
    ParallelPlan,
    ideal_pipeline_time,
    simulate,
    transformer_lm_graph,
    wafer_scale,
)
from proptools import given


def _graph(plan, layers=8, H=512, S=256):
    return transformer_lm_graph("t", layers, H, 8, S, plan.microbatch * plan.dp,
                                vocab=4096)


def test_eq1_is_lower_bound():
    hw = wafer_scale()
    plan = ParallelPlan(pp=4, dp=2, tp=4, microbatch=2, global_batch=64,
                        schedule="1f1b")
    res = simulate(_graph(plan), hw, plan, collect_timeline=True)
    import collections
    fdbd = collections.defaultdict(float)
    for (s, ph, mb, t0, t1) in res.timeline:
        if ph in ("FD", "BD") and mb == 0:
            fdbd[s] += t1 - t0
    lb = ideal_pipeline_time(list(fdbd.values()), plan.num_microbatches)
    assert lb <= res.total_time * (1 + 1e-6)


def test_more_microbatches_reduce_bubble():
    hw = wafer_scale()
    bubbles = []
    for mb_count in (2, 4, 8):
        gb = 16 * mb_count
        plan = ParallelPlan(pp=4, dp=2, tp=4, microbatch=8 // 8 + 1,
                            global_batch=gb, schedule="1f1b")
        plan = ParallelPlan(pp=4, dp=2, tp=4, microbatch=1,
                            global_batch=2 * mb_count, schedule="1f1b")
        res = simulate(_graph(plan), hw, plan)
        bubbles.append(res.bubble_ratio)
    assert bubbles[0] > bubbles[-1]


def test_gpipe_slower_or_equal_1f1b_memory_and_time():
    hw = wafer_scale()
    base = dict(pp=4, dp=2, tp=4, microbatch=1, global_batch=32)
    res_g = simulate(_graph(ParallelPlan(schedule="gpipe", **base)), hw,
                     ParallelPlan(schedule="gpipe", **base))
    res_f = simulate(_graph(ParallelPlan(schedule="1f1b", **base)), hw,
                     ParallelPlan(schedule="1f1b", **base))
    assert max(m.activations for m in res_f.stage_memory) <= \
        max(m.activations for m in res_g.stage_memory)
    # same ideal compute => comparable times (1F1B not slower by much)
    assert res_f.total_time <= res_g.total_time * 1.2


def test_recompute_increases_time_reduces_memory():
    hw = wafer_scale()
    base = dict(pp=2, dp=2, tp=4, microbatch=2, global_batch=32)
    r_no = simulate(_graph(ParallelPlan(recompute="never", **base)), hw,
                    ParallelPlan(recompute="never", **base))
    r_yes = simulate(_graph(ParallelPlan(recompute="always", **base)), hw,
                     ParallelPlan(recompute="always", **base))
    assert r_yes.total_time > r_no.total_time
    assert max(m.activations for m in r_yes.stage_memory) <= \
        max(m.activations for m in r_no.stage_memory)
    assert r_yes.recompute and not r_no.recompute


def test_inference_steady_state_excludes_drain():
    hw = wafer_scale()
    plan = ParallelPlan(pp=4, dp=2, tp=4, microbatch=2, global_batch=64,
                        training=False)
    res = simulate(_graph(plan), hw, plan)
    assert res.throughput > 0
    # steady-state rate beats naive total/batch accounting (drain excluded)
    assert res.throughput >= plan.global_batch / res.total_time * 0.99


def test_dp_comm_overlap_gu():
    """DP gradient all-reduce overlaps trailing compute (Fig. 5 note):
    the run with DP comm is far cheaper than serial comm + compute."""
    hw = wafer_scale()
    plan = ParallelPlan(pp=2, dp=8, tp=1, microbatch=1, global_batch=32)
    res = simulate(_graph(plan), hw, plan)
    assert res.total_time > 0


def test_interleaved_1f1b_reduces_bubble_time():
    """Table II '(interleaved)1F1B': virtual stages shrink warmup bubble."""
    hw = wafer_scale()
    g = transformer_lm_graph("t", 16, 512, 8, 256, 2, vocab=4096)
    base = dict(dp=2, tp=4, microbatch=1, global_batch=16, schedule="1f1b")
    r1 = simulate(g, hw, ParallelPlan(pp=4, interleave=1, **base))
    r2 = simulate(g, hw, ParallelPlan(pp=4, interleave=2, **base))
    assert r2.total_time < r1.total_time


@given(n_cases=6)
def test_prop_throughput_monotone_in_compute(rng, case):
    """Doubling every op's work cannot increase simulated throughput."""
    hw = wafer_scale()
    H = int(rng.choice([256, 512]))
    plan = ParallelPlan(pp=2, dp=2, tp=4, microbatch=1,
                        global_batch=int(rng.choice([8, 16])))
    g_small = transformer_lm_graph("s", 4, H, 8, 128, plan.dp, vocab=2048)
    g_big = transformer_lm_graph("b", 8, H, 8, 128, plan.dp, vocab=2048)
    r_small = simulate(g_small, hw, plan)
    r_big = simulate(g_big, hw, plan)
    assert r_big.throughput <= r_small.throughput * (1 + 1e-9)
