"""Scale-out fabric subsystem (``repro.fabric``): spec round-trips and
routing arithmetic, simulated collective costs vs the closed-form
alpha-beta lower bounds (per level), the FabricModel facade on the event
core (single-chip transparency, fabric trace lanes, Chrome export),
serial-vs-pool bit-identity for fabric-spanning sweeps, and the fabric
axes in hardware co-design (exhaustive and guided paths)."""

import dataclasses
import json

import pytest

from repro.api import (
    Experiment,
    HardwareSearchSpace,
    Layout,
    PlannerCfg,
    SearchSpace,
    chrome_trace,
    plan_codesign,
)
from repro.configs import get_config
from repro.core import (
    DRAMSpec,
    Environment,
    HardwareSpec,
    HierarchicalSpec,
    MeshSpec,
    NoCMode,
    ParallelPlan,
    TileSpec,
    simulate,
    transformer_lm_graph,
    wafer_scale,
)
from repro.core.hardware import tiled_cluster
from repro.core.topology import spec_of
from repro.core.trace import KIND_FABRIC
from repro.fabric import (
    FABRIC_PRESETS,
    FabricLevel,
    FabricSpec,
    alpha_beta_lower_bound,
    cluster_2x2,
    rack_2x2x2,
)
from repro.fabric.model import FabricModel
from repro.search import FULL, Fidelity
from repro.serving import ServingSpec, WorkloadSpec

GB = 1e9


# ---------------------------------------------------------------------------
# spec: validation, shape/routing arithmetic, serialization
# ---------------------------------------------------------------------------

def test_fabric_level_validation():
    with pytest.raises(ValueError, match="degree"):
        FabricLevel("board", degree=0, bandwidth=1 * GB)
    with pytest.raises(ValueError, match="bandwidth"):
        FabricLevel("board", degree=2, bandwidth=0)
    with pytest.raises(ValueError, match="latency"):
        FabricLevel("board", degree=2, bandwidth=1 * GB, latency=-1e-6)
    with pytest.raises(ValueError, match="algorithm"):
        FabricLevel("board", degree=2, bandwidth=1 * GB, algorithm="magic")


def test_fabric_spec_validation():
    with pytest.raises(ValueError, match="at least one level"):
        FabricSpec(levels=())
    with pytest.raises(ValueError, match="collective"):
        FabricSpec(levels=(FabricLevel("b", 2, 1 * GB),), collective="nope")


@pytest.mark.parametrize("preset", sorted(FABRIC_PRESETS))
def test_fabric_spec_json_round_trip(preset):
    fab = FABRIC_PRESETS[preset]()
    back = FabricSpec.from_json(fab.to_json())
    assert back == fab
    # and a second trip is stable (no lossy normalization)
    assert FabricSpec.from_json(back.to_json()) == back
    assert json.loads(fab.to_json())["name"] == preset


def test_cluster_2x2_shape_and_routing():
    fab = cluster_2x2()
    assert fab.num_chips == 4
    assert fab.degrees == (2, 2)
    # 4 board-level up/down pairs + 2 node-level pairs
    assert fab.num_links() == 12
    assert fab.chips_per_child(0) == 1 and fab.chips_per_child(1) == 2
    assert fab.chips_per_group(0) == 2 and fab.chips_per_group(1) == 4
    # same board: one hop through the board switch
    assert fab.route(0, 1) == [fab.up_link(0, 0), fab.down_link(0, 1)]
    # cross-board: climb board + node, descend node + board
    assert fab.route(0, 3) == [
        fab.up_link(0, 0), fab.up_link(1, 0),
        fab.down_link(1, 3), fab.down_link(0, 3)]
    assert fab.route(2, 2) == []
    # link ids partition into levels with the right bandwidths
    assert {fab.link_level(l) for l in range(8)} == {0}
    assert {fab.link_level(l) for l in range(8, 12)} == {1}
    assert fab.link_bandwidth(0) == 100 * GB
    assert fab.link_bandwidth(8) == 25 * GB
    with pytest.raises(ValueError, match="out of range"):
        fab.link_level(12)


def test_with_level_derivation():
    fab = cluster_2x2()
    derived = fab.with_level(1, bandwidth=50 * GB)
    assert derived.levels[1].bandwidth == 50 * GB
    assert derived.levels[0] == fab.levels[0]
    assert fab.levels[1].bandwidth == 25 * GB      # original untouched


def test_hardware_spec_carries_fabric_through_json():
    hw = tiled_cluster()
    assert hw.fabric is not None and hw.num_chips == 4
    assert hw.num_devices == 4 * hw.chip_devices
    back = HardwareSpec.from_json(hw.to_json())
    assert back.fabric == hw.fabric
    assert back.num_devices == hw.num_devices
    # fabric-less specs stay fabric-less (no key in the dict at all)
    ws = wafer_scale()
    assert ws.fabric is None and ws.num_chips == 1
    assert "fabric" not in ws.to_dict()


# ---------------------------------------------------------------------------
# satellite: HierarchicalSpec round-trips with full fidelity
# ---------------------------------------------------------------------------

def test_hierarchical_spec_round_trips_through_hardware_json():
    ws = wafer_scale()
    assert isinstance(spec_of(ws.topology), HierarchicalSpec)
    once = HardwareSpec.from_json(ws.to_json())
    assert spec_of(once.topology) == spec_of(ws.topology)
    assert isinstance(spec_of(once.topology), HierarchicalSpec)
    twice = HardwareSpec.from_json(once.to_json())
    assert spec_of(twice.topology) == spec_of(ws.topology)


# ---------------------------------------------------------------------------
# collective costs vs closed-form alpha-beta bounds
# ---------------------------------------------------------------------------

def _one_device_chips(fabric: FabricSpec) -> HardwareSpec:
    """One device per chip with an effectively-free intra-chip NoC, so
    the simulated collective time is the pure fabric schedule cost."""
    return HardwareSpec(
        name=f"fab_{fabric.name}", topology=MeshSpec(1, 1, intra_bw=1e12),
        tile=TileSpec(flops=1e12, sram_bytes=1e6),
        dram=DRAMSpec(bandwidth=1e12), fabric=fabric)


def _fabric_collective_time(fabric: FabricSpec, kind: str, nbytes: float,
                            mode=NoCMode.DETAILED) -> float:
    env = Environment()
    fm = FabricModel(env, _one_device_chips(fabric), mode=mode)
    proc = env.process(fm.collective(kind, list(range(fabric.num_chips)),
                                     nbytes))
    env.run(until_event=proc)
    return env.now


def per_level_allreduce_bound(fab: FabricSpec, nbytes: float) -> float:
    """The payload entering level L is the level-(L-1) reduce-scatter
    output ``n / chips_per_child(L)``; no schedule moves it across the
    level's links in less than the ring term ``2(d-1)/d * payload/bw``."""
    return sum(
        alpha_beta_lower_bound("all_reduce", lvl.degree,
                               nbytes / fab.chips_per_child(i), lvl.bandwidth)
        for i, lvl in enumerate(fab.levels))


def test_single_level_ring_allreduce_matches_closed_form():
    """Flat ring on one switch tier: 2(p-1) rounds, each moving n/p over
    disjoint up/down link pairs -> 2(p-1) * (n/p/bw + 2*lat) exactly."""
    p, bw, lat, nbytes = 4, 10 * GB, 1e-6, 4e6
    fab = FabricSpec(name="flat", collective="ring",
                     levels=(FabricLevel("board", p, bw, latency=lat),))
    expect = 2 * (p - 1) * (nbytes / p / bw + 2 * lat)
    t_det = _fabric_collective_time(fab, "all_reduce", nbytes)
    assert t_det == pytest.approx(expect, rel=1e-9)
    # ring rounds use disjoint links, so macro (union-footprint hold)
    # agrees with the per-round detailed schedule
    t_mac = _fabric_collective_time(fab, "all_reduce", nbytes, NoCMode.MACRO)
    assert t_mac == pytest.approx(t_det, rel=1e-9)
    # and the cost respects (here: exceeds, due to latency) the bound
    assert t_det >= alpha_beta_lower_bound("all_reduce", p, nbytes, bw)


@pytest.mark.parametrize("fab", [cluster_2x2(), rack_2x2x2()],
                         ids=["cluster_2x2", "rack_2x2x2"])
@pytest.mark.parametrize("family", ["ring", "tree", "hd", "hierarchical"])
def test_fabric_allreduce_respects_per_level_bound(fab, family):
    for kb in (64, 1024):
        nbytes = kb * 1e3
        spec = dataclasses.replace(fab, collective=family)
        t = _fabric_collective_time(spec, "all_reduce", nbytes)
        assert t >= per_level_allreduce_bound(fab, nbytes) * (1 - 1e-9), \
            f"{family} @ {kb}KB beats the per-level alpha-beta bound"


def test_hierarchical_beats_flat_ring_at_scale():
    """The latency regime hierarchical collectives exist for: at 8 chips
    and a small payload, per-level RS/AG wins over the flat ring (fewer
    rounds, upper-tier traffic shrunk by the level fan-in)."""
    fab = rack_2x2x2()
    nbytes = 64e3
    t_hier = _fabric_collective_time(
        dataclasses.replace(fab, collective="hierarchical"),
        "all_reduce", nbytes)
    t_ring = _fabric_collective_time(
        dataclasses.replace(fab, collective="ring"), "all_reduce", nbytes)
    assert t_hier <= t_ring


def test_reduce_scatter_and_all_gather_bounds():
    fab = cluster_2x2()
    p, nbytes = fab.num_chips, 1e6
    for kind in ("reduce_scatter", "all_gather"):
        t = _fabric_collective_time(fab, kind, nbytes)
        bound = sum(
            alpha_beta_lower_bound(kind, lvl.degree,
                                   nbytes / fab.chips_per_child(i),
                                   lvl.bandwidth)
            for i, lvl in enumerate(fab.levels))
        assert t >= bound * (1 - 1e-9)
        assert t > 0
    # pairwise all-to-all (MoE dispatch): every chip exchanges n/p with
    # every other chip; the top tier alone must carry the bisection half
    t = _fabric_collective_time(fab, "all_to_all", nbytes)
    top = fab.levels[-1]
    cross = (p // 2) * (p // 2) * (nbytes / p)      # bytes crossing the top
    assert t >= cross / (top.bandwidth * fab.instances(1)) * (1 - 1e-9)


def test_fabric_counters_and_modes():
    """bytes_moved/transfer_count tick; analytical <= macro/detailed."""
    fab = cluster_2x2()
    env = Environment()
    fm = FabricModel(env, _one_device_chips(fab), mode=NoCMode.DETAILED)
    proc = env.process(fm.collective("all_reduce", [0, 1, 2, 3], 1e6))
    env.run(until_event=proc)
    assert fm.fabric_bytes > 0 and fm.fabric_transfers > 0
    t_det = env.now
    t_ana = _fabric_collective_time(fab, "all_reduce", 1e6,
                                    NoCMode.ANALYTICAL)
    assert 0 < t_ana <= t_det * (1 + 1e-9)


# ---------------------------------------------------------------------------
# FabricModel facade on the event core
# ---------------------------------------------------------------------------

def _small_chip(fabric=None) -> HardwareSpec:
    return HardwareSpec(
        name="chip2x2", topology=MeshSpec(2, 2, intra_bw=512 * GB),
        tile=TileSpec(flops=16e12, sram_bytes=4e6),
        dram=DRAMSpec(bandwidth=1e11, channels=2), fabric=fabric)


def test_degenerate_fabric_is_transparent():
    """A one-chip fabric must be a bit-identical no-op: every collective,
    transfer, and DRAM access localizes to chip 0 with resource base 0,
    so the trace matches the plain NoCModel/DRAMModel path exactly."""
    solo = FabricSpec(name="solo",
                      levels=(FabricLevel("board", 1, 1 * GB),))
    plan = ParallelPlan(pp=2, dp=1, tp=2, microbatch=1, global_batch=4)
    graph = transformer_lm_graph("t", 2, 256, 8, 128, plan.microbatch,
                                 vocab=2048)
    runs = {}
    for key, fabric in (("plain", None), ("fabric", solo)):
        runs[key] = simulate(graph, _small_chip(fabric), plan,
                             noc_mode=NoCMode.DETAILED,
                             collect_timeline=True)
    assert runs["fabric"].total_time == runs["plain"].total_time
    assert runs["fabric"].trace == runs["plain"].trace
    assert not any(int(k) == KIND_FABRIC for k in runs["fabric"].trace.kind)


def test_cluster_sim_emits_fabric_lanes_and_chrome_export():
    """Acceptance: the 4-chip (2 boards x 2 chips) cluster preset
    simulates end-to-end with the dp gradient all-reduce spanning chips,
    and the shared fabric links appear as first-class COMM lanes in the
    trace and the Chrome export."""
    exp = Experiment(arch="yi-6b", hardware=tiled_cluster(), seq_len=128,
                     global_batch=8, collect_timeline=True,
                     search=SearchSpace(degrees=((2, 8, 4),),
                                        microbatch_sizes=(1,),
                                        layouts=(Layout.S_SHAPE,)))
    rep = exp.sweep(workers=0, return_timelines=True)
    assert len(rep.runs) == 1
    run = rep.runs[0]
    assert run.total_time > 0
    fabric_lanes = {int(r) for k, r in zip(run.trace.kind, run.trace.resource)
                    if int(k) == KIND_FABRIC}
    assert fabric_lanes, "chip-spanning plan produced no fabric intervals"
    # occupancy rolls the lanes up too
    occ = run.trace.resource_occupancy(KIND_FABRIC)
    assert occ and all(v > 0 for v in occ.values())
    # Chrome export: fabric links get their own process with flink threads
    chrome = chrome_trace(run.trace)
    names = [e["args"]["name"] for e in chrome["traceEvents"]
             if e.get("name") == "process_name"]
    assert any(n.endswith("fabric links") for n in names)
    threads = [e["args"]["name"] for e in chrome["traceEvents"]
               if e.get("name") == "thread_name"]
    assert any(t.startswith("flink") for t in threads)


def test_serial_and_pool_fabric_sweeps_ship_identical_traces():
    """Satellite gate: a fabric-spanning sweep is bit-identical between
    the serial executor and the process pool."""
    exp = Experiment(arch="yi-6b", hardware=tiled_cluster(), seq_len=128,
                     global_batch=8, collect_timeline=True,
                     search=SearchSpace(degrees=((2, 8, 4), (4, 4, 4)),
                                        microbatch_sizes=(1,),
                                        layouts=(Layout.S_SHAPE,)))
    serial = exp.sweep(workers=0, return_timelines=True)
    pooled = exp.sweep(workers=2, return_timelines=True)
    assert pooled.executor.startswith("process")
    assert len(serial.runs) == len(pooled.runs) == 2
    for a, b in zip(serial.runs, pooled.runs):
        assert a.plan == b.plan
        assert a.total_time == b.total_time
        assert a.trace == b.trace


# ---------------------------------------------------------------------------
# co-design over fabric axes
# ---------------------------------------------------------------------------

def test_fabric_axes_validate_and_require_a_fabric():
    with pytest.raises(ValueError, match="collective"):
        HardwareSearchSpace(fabric_collectives=("warp",))
    space = HardwareSearchSpace(fabric_bw=(12.5 * GB, 25 * GB))
    with pytest.raises(ValueError, match="fabric"):
        space.enumerate_specs(wafer_scale())      # base has no fabric


def test_fabric_axes_enumerate_derived_specs():
    space = HardwareSearchSpace(fabric_bw=(12.5 * GB, 25 * GB),
                                fabric_collectives=("hierarchical", "ring"))
    variants = space.enumerate_specs(tiled_cluster())
    assert len(variants) == 4
    top = tiled_cluster().fabric.num_levels - 1
    bws = {v.fabric.levels[top].bandwidth for v in variants}
    assert bws == {12.5 * GB, 25 * GB}
    assert {v.fabric.collective for v in variants} == {"hierarchical", "ring"}
    assert len({v.name for v in variants}) == 4   # distinct derived names
    for v in variants:
        assert HardwareSpec.from_json(v.to_json()).fabric == v.fabric


@pytest.mark.parametrize("strategy", ["exhaustive", "sh"])
def test_plan_codesign_over_fabric_axis_round_trips(strategy):
    """Acceptance: co-design over a fabric axis returns a winner whose
    FabricSpec survives the JSON round trip — through the exhaustive
    product and the guided (successive-halving) path alike."""
    guided = {} if strategy == "exhaustive" else dict(
        search_strategy="sh", search_budget=2, search_seed=0)
    cfg = PlannerCfg(
        global_batch=8, seq_len=128, max_plans=2, microbatch_sizes=(1,),
        layouts=(Layout.S_SHAPE,),
        hardware_search=HardwareSearchSpace(fabric_bw=(12.5 * GB, 25 * GB)),
        **guided)
    res = plan_codesign(get_config("yi-6b"), tiled_cluster(), cfg)
    winner = res.hardware
    assert winner.fabric is not None
    top = winner.fabric.num_levels - 1
    assert winner.fabric.levels[top].bandwidth in (12.5 * GB, 25 * GB)
    back = HardwareSpec.from_json(winner.to_json())
    assert back.fabric == winner.fabric
    if strategy == "sh":
        assert res.report.search is not None
        assert res.report.search.full_fidelity_sims <= 2


# ---------------------------------------------------------------------------
# satellite: serving-rung fidelity truncation (slo objective x guided search)
# ---------------------------------------------------------------------------

def test_fidelity_truncates_serving_workloads():
    fid = Fidelity(name="rung", max_requests=4)
    assert not fid.is_full
    spec = ServingSpec(workload=WorkloadSpec(num_requests=64))
    cut = fid.apply_serving(spec)
    assert cut.workload.num_requests == 4
    assert spec.workload.num_requests == 64       # original untouched
    # replay workloads slice the explicit request list too
    rows = [[0.1 * i, 8, 4] for i in range(6)]
    replay = ServingSpec(workload=WorkloadSpec(kind="replay", requests=rows,
                                               num_requests=6))
    cut = fid.apply_serving(replay)
    assert cut.workload.requests == rows[:4]
    assert cut.workload.num_requests == 4
    # already small enough / full fidelity: pass through unchanged
    small = ServingSpec(workload=WorkloadSpec(num_requests=3))
    assert fid.apply_serving(small) is small
    assert FULL.apply_serving(spec) is spec
    assert fid.apply_serving(None) is None
    with pytest.raises(ValueError, match="max_requests"):
        Fidelity(name="bad", max_requests=0)
